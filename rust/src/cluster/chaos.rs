//! Deterministic fault injection for the cluster runtime.
//!
//! A `ChaosPlan` is a scripted schedule of faults parsed from
//! `DSFACTO_CHAOS=<spec>` (or `--chaos <spec>`), applied at the wire
//! seams of the control plane and the token ring. Because the e2e
//! oracle is *bitwise* model equality after recovery (mean-mode
//! recompute is arrival-order independent), a replayable schedule is
//! enough: which concrete frame happens to be the Nth is timing
//! dependent, but the recovered model must be identical regardless.
//!
//! Spec grammar — `;`-separated directives:
//!
//! ```text
//! drop:ring:N     drop the Nth (0-based) outbound ring frame
//! drop:ctrl:N     drop the Nth outbound control frame
//! dup:ring:N      send the Nth outbound ring frame twice
//! dup:ctrl:N      send the Nth outbound control frame twice
//! delay:ring:N:MS sleep MS ms before sending the Nth ring frame
//! delay:ctrl:N:MS sleep MS ms before sending the Nth control frame
//! kill:E          exit(9) once this process observes epoch E complete
//! refuse:MS       drop inbound connections for the first MS ms of life
//! ```
//!
//! Faults apply only to real socket traffic: the self-rank short
//! circuit inside `TcpTransport::send` never touches the plan.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

/// Which wire a frame is crossing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scope {
    /// Token-ring frames between workers (`TcpTransport`).
    Ring,
    /// Control-plane frames between driver and workers.
    Ctrl,
}

impl Scope {
    fn idx(self) -> usize {
        match self {
            Scope::Ring => 0,
            Scope::Ctrl => 1,
        }
    }
}

/// What the seam should do with one outbound frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SendFate {
    /// Pretend the network ate it: count it, don't write it.
    Drop,
    /// Normal delivery.
    Deliver,
    /// Write the identical bytes (same sequence number) twice.
    Duplicate,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Directive {
    Drop(Scope, u64),
    Dup(Scope, u64),
    Delay(Scope, u64, u64),
}

/// A parsed, replayable fault schedule for one process.
pub struct ChaosPlan {
    directives: Vec<Directive>,
    sent: [AtomicU64; 2],
    born: Instant,
    kill_epoch: Option<u32>,
    killed: AtomicBool,
    refuse: Option<Duration>,
}

fn parse_scope(s: &str, directive: &str) -> Result<Scope> {
    match s {
        "ring" => Ok(Scope::Ring),
        "ctrl" => Ok(Scope::Ctrl),
        other => bail!("chaos: unknown scope '{other}' in '{directive}' (want ring|ctrl)"),
    }
}

impl ChaosPlan {
    /// Parses a chaos spec; errors name the offending directive.
    pub fn parse(spec: &str) -> Result<ChaosPlan> {
        let mut plan = ChaosPlan {
            directives: Vec::new(),
            sent: [AtomicU64::new(0), AtomicU64::new(0)],
            born: Instant::now(),
            kill_epoch: None,
            killed: AtomicBool::new(false),
            refuse: None,
        };
        for raw in spec.split(';') {
            let d = raw.trim();
            if d.is_empty() {
                continue;
            }
            let parts: Vec<&str> = d.split(':').collect();
            let num = |s: &str| -> Result<u64> {
                s.parse::<u64>()
                    .with_context(|| format!("chaos: bad number '{s}' in '{d}'"))
            };
            match (parts[0], parts.len()) {
                ("drop", 3) => {
                    plan.directives
                        .push(Directive::Drop(parse_scope(parts[1], d)?, num(parts[2])?));
                }
                ("dup", 3) => {
                    plan.directives
                        .push(Directive::Dup(parse_scope(parts[1], d)?, num(parts[2])?));
                }
                ("delay", 4) => {
                    plan.directives.push(Directive::Delay(
                        parse_scope(parts[1], d)?,
                        num(parts[2])?,
                        num(parts[3])?,
                    ));
                }
                ("kill", 2) => plan.kill_epoch = Some(num(parts[1])? as u32),
                ("refuse", 2) => plan.refuse = Some(Duration::from_millis(num(parts[1])?)),
                _ => bail!(
                    "chaos: unparseable directive '{d}' \
                     (want drop:SCOPE:N, dup:SCOPE:N, delay:SCOPE:N:MS, kill:E, refuse:MS)"
                ),
            }
        }
        Ok(plan)
    }

    /// Resolves the plan for this process: an explicit `--chaos` flag
    /// wins, else the `DSFACTO_CHAOS` environment variable, else none.
    pub fn from_flag_or_env(flag: Option<&str>) -> Result<Option<std::sync::Arc<ChaosPlan>>> {
        let spec = match flag {
            Some(s) => Some(s.to_string()),
            None => std::env::var("DSFACTO_CHAOS").ok(),
        };
        match spec.as_deref().map(str::trim) {
            None | Some("") => Ok(None),
            Some(s) => Ok(Some(std::sync::Arc::new(ChaosPlan::parse(s)?))),
        }
    }

    /// Consumes one outbound frame slot on `scope`: applies any delay
    /// directive inline, then reports the frame's fate. Each call
    /// advances the per-scope frame counter exactly once.
    pub fn on_send(&self, scope: Scope) -> SendFate {
        let n = self.sent[scope.idx()].fetch_add(1, Ordering::Relaxed);
        let mut fate = SendFate::Deliver;
        for d in &self.directives {
            match *d {
                Directive::Delay(s, at, ms) if s == scope && at == n => {
                    std::thread::sleep(Duration::from_millis(ms));
                }
                Directive::Drop(s, at) if s == scope && at == n => fate = SendFate::Drop,
                Directive::Dup(s, at) if s == scope && at == n => fate = SendFate::Duplicate,
                _ => {}
            }
        }
        fate
    }

    /// How many outbound frames `scope` has presented to the plan.
    pub fn frames_seen(&self, scope: Scope) -> u64 {
        self.sent[scope.idx()].load(Ordering::Relaxed)
    }

    /// True exactly once, when `epoch` first reaches the scripted kill
    /// point. The caller is expected to `process::exit(9)`.
    pub fn kill_due(&self, epoch: u32) -> bool {
        match self.kill_epoch {
            Some(e) if epoch >= e => !self.killed.swap(true, Ordering::Relaxed),
            _ => false,
        }
    }

    /// Kills the process if the scripted kill epoch has been reached.
    pub fn kill_if_due(&self, epoch: u32, who: &str) {
        if self.kill_due(epoch) {
            eprintln!("dsfacto chaos: {who} exiting at epoch {epoch} (scripted kill)");
            std::process::exit(9);
        }
    }

    /// True while the scripted connection-refusal window is open.
    pub fn refusing(&self) -> bool {
        match self.refuse {
            Some(window) => self.born.elapsed() < window,
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_directive_kind() {
        let plan =
            ChaosPlan::parse("drop:ring:3; dup:ctrl:0; delay:ring:1:25; kill:4; refuse:10").unwrap();
        assert_eq!(plan.directives.len(), 3);
        assert_eq!(plan.kill_epoch, Some(4));
        assert_eq!(plan.refuse, Some(Duration::from_millis(10)));
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "drop:3",
            "drop:lan:3",
            "dup:ring:x",
            "delay:ring:1",
            "explode:now",
            "kill:ring:2",
        ] {
            assert!(ChaosPlan::parse(bad).is_err(), "accepted bad spec '{bad}'");
        }
    }

    #[test]
    fn empty_and_whitespace_specs_are_inert() {
        let plan = ChaosPlan::parse(" ; ;; ").unwrap();
        assert_eq!(plan.on_send(Scope::Ring), SendFate::Deliver);
        assert!(!plan.kill_due(100));
        assert!(!plan.refusing());
    }

    #[test]
    fn fates_fire_at_the_scripted_indices_per_scope() {
        let plan = ChaosPlan::parse("drop:ring:1;dup:ring:2;drop:ctrl:0").unwrap();
        assert_eq!(plan.on_send(Scope::Ring), SendFate::Deliver); // ring #0
        assert_eq!(plan.on_send(Scope::Ctrl), SendFate::Drop); // ctrl #0
        assert_eq!(plan.on_send(Scope::Ring), SendFate::Drop); // ring #1
        assert_eq!(plan.on_send(Scope::Ring), SendFate::Duplicate); // ring #2
        assert_eq!(plan.on_send(Scope::Ring), SendFate::Deliver); // ring #3
        assert_eq!(plan.on_send(Scope::Ctrl), SendFate::Deliver); // ctrl #1
        assert_eq!(plan.frames_seen(Scope::Ring), 4);
        assert_eq!(plan.frames_seen(Scope::Ctrl), 2);
    }

    #[test]
    fn kill_fires_exactly_once_at_or_after_the_epoch() {
        let plan = ChaosPlan::parse("kill:3").unwrap();
        assert!(!plan.kill_due(2));
        assert!(plan.kill_due(3));
        assert!(!plan.kill_due(3), "kill must fire once");
        assert!(!plan.kill_due(7));
    }

    #[test]
    fn refusal_window_opens_then_closes() {
        let plan = ChaosPlan::parse("refuse:40").unwrap();
        assert!(plan.refusing());
        std::thread::sleep(Duration::from_millis(60));
        assert!(!plan.refusing());
    }

    #[test]
    fn explicit_flag_specs_parse_or_error() {
        assert!(ChaosPlan::from_flag_or_env(Some("kill:1"))
            .unwrap()
            .is_some());
        assert!(ChaosPlan::from_flag_or_env(Some("bogus")).is_err());
    }
}
