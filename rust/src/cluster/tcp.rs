//! Real TCP transport over the token codec (loopback multi-process mode).
//!
//! Each worker owns one listening socket; `send(dst, tok)` writes a
//! length-prefixed codec frame to a (lazily established, then cached)
//! connection to `dst`'s listener. A reader thread per accepted connection
//! pushes decoded tokens into the worker's local inbox.
//!
//! This is the transport the `--transport tcp` CLI mode uses; the engine
//! semantics are identical to [`super::LocalTransport`], only the medium
//! changes, which is exactly the property the Fig. 6 multi-machine
//! comparison needs.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use super::chaos::{ChaosPlan, Scope, SendFate};
use super::codec::{FrameOpener, FrameSealer, Opened, WirePrecision};
use super::retry::{Attempt, RetryPolicy, SystemClock};
use super::{codec, LocalTransport, Transport, TransportStats};
use crate::nomad::token::Token;

/// Token frames are capped at 1 MiB; the envelope adds a small header
/// (+ tag) on top.
const MAX_RING_ENVELOPE: usize = (1 << 20) + 64;

/// TCP loopback transport for `p` workers.
pub struct TcpTransport {
    inbox: LocalTransport,
    addrs: Vec<SocketAddr>,
    conns: Vec<Mutex<Option<TcpStream>>>,
    /// In multi-process mode ([`TcpTransport::remote`]), the one rank this
    /// process hosts: sends to it short-circuit the socket, and every
    /// inbound connection feeds its inbox. `None` = all ranks in-process.
    rank: Option<usize>,
    /// How long `connect` keeps retrying a peer whose listener isn't up
    /// yet (cluster workers start in arbitrary order).
    connect_deadline: Duration,
    /// `Some(k)` when the engine circulates lane-padded token payloads:
    /// frames are stripped to the K-strided wire form on send and
    /// re-padded on receive, so the bytes on the socket are identical to
    /// the unpadded era. `None` = payloads are already K-strided.
    wire_k: Option<usize>,
    /// Numeric format of the token payloads on the socket. Only
    /// meaningful with `wire_k = Some(_)` (the strip/re-pad seam is where
    /// values are converted); the in-process [`TcpTransport::new`] mode
    /// is always f32. Both ends of a ring must agree — the cluster
    /// control plane negotiates this at Join.
    precision: WirePrecision,
    /// HMAC key for the stream envelope (`None` = unauthenticated, the
    /// in-process loopback mode).
    key: Option<[u8; 32]>,
    /// Scripted fault schedule applied to real socket sends only.
    chaos: Option<Arc<ChaosPlan>>,
    /// One envelope sealer (sequence counter) per outbound peer.
    sealers: Vec<FrameSealer>,
    bytes: AtomicU64,
    messages: AtomicU64,
    /// Sends dropped because a peer never became reachable (or its
    /// connection broke mid-write). Zero in any healthy run.
    send_failures: AtomicU64,
    down: Arc<AtomicBool>,
    accept_threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl TcpTransport {
    /// Binds `p` listeners on ephemeral loopback ports and starts acceptor
    /// threads that feed each worker's inbox. `wire_k` declares the
    /// circulating tokens' payload layout (see the field docs).
    pub fn new(p: usize, wire_k: Option<usize>) -> Result<Arc<Self>> {
        let mut listeners = Vec::with_capacity(p);
        let mut addrs = Vec::with_capacity(p);
        for _ in 0..p {
            let l = TcpListener::bind("127.0.0.1:0").context("bind loopback")?;
            addrs.push(l.local_addr()?);
            listeners.push(l);
        }
        let t = Arc::new(TcpTransport {
            inbox: LocalTransport::new(p),
            addrs,
            conns: (0..p).map(|_| Mutex::new(None)).collect(),
            rank: None,
            connect_deadline: Duration::from_secs(5),
            wire_k,
            precision: WirePrecision::F32,
            key: None,
            chaos: None,
            sealers: (0..p).map(|_| FrameSealer::new(None)).collect(),
            bytes: AtomicU64::new(0),
            messages: AtomicU64::new(0),
            send_failures: AtomicU64::new(0),
            down: Arc::new(AtomicBool::new(false)),
            accept_threads: Mutex::new(Vec::new()),
        });
        for (w, listener) in listeners.into_iter().enumerate() {
            let tt = Arc::clone(&t);
            let down = Arc::clone(&t.down);
            listener.set_nonblocking(true)?;
            let h = std::thread::Builder::new()
                .name(format!("tcp-accept-{w}"))
                .spawn(move || {
                    while !down.load(Ordering::Relaxed) {
                        match listener.accept() {
                            Ok((stream, _)) => {
                                stream.set_nodelay(true).ok();
                                let tt2 = Arc::clone(&tt);
                                let down2 = Arc::clone(&down);
                                std::thread::Builder::new()
                                    .name(format!("tcp-read-{w}"))
                                    .spawn(move || tt2.read_loop(w, stream, down2))
                                    .expect("spawn reader");
                            }
                            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                std::thread::sleep(Duration::from_millis(2));
                            }
                            Err(_) => break,
                        }
                    }
                })
                .expect("spawn acceptor");
            t.accept_threads.lock().unwrap().push(h);
        }
        Ok(t)
    }

    fn read_loop(&self, worker: usize, mut stream: TcpStream, down: Arc<AtomicBool>) {
        if stream
            .set_read_timeout(Some(Duration::from_millis(50)))
            .is_err()
        {
            // Without the timeout this reader could not poll `down` and
            // would block forever; refuse the connection instead.
            eprintln!("dsfacto: could not set ring read timeout; dropping connection");
            return;
        }
        let mut opener = FrameOpener::new(self.key, "ring");
        let mut len_buf = [0u8; 4];
        let mut frame = Vec::new();
        while !down.load(Ordering::Relaxed) {
            match stream.read_exact(&mut len_buf) {
                Ok(()) => {}
                Err(ref e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    continue;
                }
                Err(_) => return,
            }
            let len = u32::from_le_bytes(len_buf) as usize;
            if len > MAX_RING_ENVELOPE {
                return; // corrupt frame; drop the connection
            }
            frame.resize(len, 0);
            if read_fully(&mut stream, &mut frame, &down).is_err() {
                return;
            }
            let body = match opener.open(&frame) {
                Ok(Opened::Body(b)) => b,
                // Exact retransmit (chaos dup or resend): swallow it.
                Ok(Opened::Duplicate) => continue,
                // Unauthenticated/tampered/garbled: rejection already
                // counted and logged by the opener; drop the connection.
                Err(_) => return,
            };
            let decoded = match (self.wire_k, self.precision) {
                (Some(_), WirePrecision::Bf16) => codec::decode_token_bf16(body),
                (Some(_), WirePrecision::F32) => codec::decode_token_padded(body),
                (None, _) => codec::decode_token(body),
            };
            match decoded {
                Ok(tok) => self.inbox.send(worker, tok),
                Err(_) => return,
            }
        }
    }

    /// Builds the transport for **one rank of a multi-process ring**: the
    /// passed listener (bound by the caller, so its address could be
    /// announced before the peer table existed) accepts all inbound token
    /// traffic into `rank`'s inbox; `peers[d]` is where sends to rank `d`
    /// connect. Sends to `rank` itself never touch a socket. `precision`
    /// picks the token payload wire format (`bf16` halves the factor
    /// bytes; every rank of a ring must pass the same value — the control
    /// plane enforces this at Join). `key` (from `cluster_secret`)
    /// authenticates every envelope; `chaos` is this process's scripted
    /// fault plan.
    pub fn remote(
        rank: usize,
        listener: TcpListener,
        peers: Vec<SocketAddr>,
        wire_k: Option<usize>,
        precision: WirePrecision,
        connect_deadline: Duration,
        key: Option<[u8; 32]>,
        chaos: Option<Arc<ChaosPlan>>,
    ) -> Result<Arc<Self>> {
        let p = peers.len();
        anyhow::ensure!(rank < p, "rank {rank} out of range for {p} peers");
        let t = Arc::new(TcpTransport {
            inbox: LocalTransport::new(p),
            addrs: peers,
            conns: (0..p).map(|_| Mutex::new(None)).collect(),
            rank: Some(rank),
            connect_deadline,
            wire_k,
            precision,
            key,
            chaos,
            sealers: (0..p).map(|_| FrameSealer::new(key)).collect(),
            bytes: AtomicU64::new(0),
            messages: AtomicU64::new(0),
            send_failures: AtomicU64::new(0),
            down: Arc::new(AtomicBool::new(false)),
            accept_threads: Mutex::new(Vec::new()),
        });
        listener.set_nonblocking(true)?;
        let tt = Arc::clone(&t);
        let down = Arc::clone(&t.down);
        let h = std::thread::Builder::new()
            .name(format!("tcp-accept-r{rank}"))
            .spawn(move || {
                while !down.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            if tt.chaos.as_ref().is_some_and(|c| c.refusing()) {
                                // Scripted refusal window: reset the
                                // connection so peers exercise their
                                // retry policy.
                                drop(stream);
                                continue;
                            }
                            stream.set_nodelay(true).ok();
                            let tt2 = Arc::clone(&tt);
                            let down2 = Arc::clone(&down);
                            std::thread::Builder::new()
                                .name(format!("tcp-read-r{rank}"))
                                .spawn(move || tt2.read_loop(rank, stream, down2))
                                .expect("spawn reader");
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })
            .context("spawn remote acceptor")?;
        t.accept_threads.lock().unwrap().push(h);
        Ok(t)
    }

    /// Sends dropped on the floor because a peer was unreachable past the
    /// connect deadline or a connection broke mid-write.
    pub fn send_failures(&self) -> u64 {
        self.send_failures.load(Ordering::Relaxed)
    }

    /// Connects to `dst` under the shared [`RetryPolicy`]: cluster
    /// workers come up in arbitrary order, so the first sends of a run
    /// can race the destination's listener. Shutdown aborts the retry
    /// loop immediately.
    fn connect(&self, dst: usize) -> Result<TcpStream> {
        let policy = RetryPolicy::new(
            Duration::from_millis(10),
            Duration::from_millis(200),
            self.connect_deadline,
        )
        .with_jitter_seed(0x7c90 + dst as u64);
        policy.run(&mut SystemClock, |_| {
            if self.down.load(Ordering::Relaxed) {
                return Err(Attempt::Abort(anyhow::anyhow!("transport shut down")));
            }
            match TcpStream::connect(self.addrs[dst]) {
                Ok(s) => {
                    s.set_nodelay(true).ok();
                    Ok(s)
                }
                Err(e) => Err(Attempt::Retry(anyhow::Error::new(e).context("connect"))),
            }
        })
    }
}

/// read_exact that tolerates the read timeout while waiting mid-frame.
fn read_fully(stream: &mut TcpStream, buf: &mut [u8], down: &AtomicBool) -> std::io::Result<()> {
    let mut read = 0;
    while read < buf.len() {
        if down.load(Ordering::Relaxed) {
            return Err(std::io::ErrorKind::Interrupted.into());
        }
        match stream.read(&mut buf[read..]) {
            Ok(0) => return Err(std::io::ErrorKind::UnexpectedEof.into()),
            Ok(n) => read += n,
            Err(ref e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

impl Transport for TcpTransport {
    fn send(&self, dst: usize, tok: Token) {
        // Multi-process mode: this process's own rank never crosses a
        // socket — tokens land in the inbox by pointer (the token deal
        // and the ring's self-adjacent hops at P = 1 both hit this).
        if self.rank == Some(dst) {
            self.messages.fetch_add(1, Ordering::Relaxed);
            self.inbox.send(dst, tok);
            return;
        }
        let mut frame = Vec::new();
        match (self.wire_k, self.precision) {
            (Some(k), WirePrecision::Bf16) => codec::encode_token_bf16(&tok, k, &mut frame),
            (Some(k), WirePrecision::F32) => codec::encode_token_padded(&tok, k, &mut frame),
            (None, _) => codec::encode_token(&tok, &mut frame),
        }
        let mut env = Vec::with_capacity(frame.len() + self.sealers[dst].overhead());
        self.sealers[dst].seal(&frame, &mut env);
        self.messages.fetch_add(1, Ordering::Relaxed);
        let fate = match &self.chaos {
            Some(c) => c.on_send(Scope::Ring),
            None => SendFate::Deliver,
        };
        if fate == SendFate::Drop {
            // Scripted loss: the sequence number is consumed, nothing is
            // written — the receiver observes a gap.
            return;
        }
        let mut msg = Vec::with_capacity(env.len() + 4);
        msg.extend_from_slice(&(env.len() as u32).to_le_bytes());
        msg.extend_from_slice(&env);
        let writes = if fate == SendFate::Duplicate { 2 } else { 1 };

        let mut guard = self.conns[dst].lock().unwrap();
        if guard.is_none() {
            match self.connect(dst) {
                Ok(s) => *guard = Some(s),
                Err(_) => {
                    // Shutdown race, or a peer that never came up within
                    // the connect deadline.
                    self.send_failures.fetch_add(1, Ordering::Relaxed);
                    return;
                }
            }
        }
        let mut failed = false;
        if let Some(stream) = guard.as_mut() {
            for _ in 0..writes {
                if stream.write_all(&msg).is_err() {
                    failed = true;
                    break;
                }
                self.bytes.fetch_add(msg.len() as u64, Ordering::Relaxed);
            }
        }
        if failed {
            *guard = None;
            self.send_failures.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn recv_timeout(&self, worker: usize, timeout: Duration) -> Option<Token> {
        self.inbox.recv_timeout(worker, timeout)
    }

    fn shutdown(&self) {
        self.down.store(true, Ordering::SeqCst);
        for c in &self.conns {
            *c.lock().unwrap() = None;
        }
        let mut threads = self.accept_threads.lock().unwrap();
        for h in threads.drain(..) {
            let _ = h.join();
        }
    }

    fn stats(&self) -> TransportStats {
        TransportStats {
            messages: self.messages.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
        }
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nomad::token::Phase;
    use std::time::Instant;

    fn tok(j: u32, k: usize) -> Token {
        Token {
            j,
            iter: 1,
            phase: Phase::Update,
            visits: 2,
            w: Box::from([0.5f32]),
            v: (0..k).map(|i| i as f32).collect(),
        }
    }

    #[test]
    fn tcp_roundtrip_between_workers() {
        let t = TcpTransport::new(2, None).unwrap();
        t.send(1, tok(42, 4));
        let got = t
            .recv_timeout(1, Duration::from_secs(5))
            .expect("tcp delivery");
        assert_eq!(got.j, 42);
        assert_eq!(got.v.len(), 4);
        assert!(t.stats().bytes > 0);
        t.shutdown();
    }

    #[test]
    fn tcp_many_messages_in_order() {
        let t = TcpTransport::new(3, None).unwrap();
        for j in 0..100 {
            t.send(2, tok(j, 8));
        }
        for j in 0..100 {
            let got = t.recv_timeout(2, Duration::from_secs(5)).expect("msg");
            assert_eq!(got.j, j);
        }
        t.shutdown();
    }

    #[test]
    fn tcp_padded_layout_survives_the_k_strided_wire() {
        let k = 5usize;
        let kp = crate::kernel::padded_k(k);
        let ncols = 2usize;
        let mut v = vec![0f32; ncols * kp];
        for bi in 0..ncols {
            for kk in 0..k {
                v[bi * kp + kk] = (bi * 10 + kk) as f32 + 0.5;
            }
        }
        let padded = Token {
            j: 3,
            iter: 1,
            phase: Phase::Update,
            visits: 0,
            w: Box::from([0.5f32, -1.0]),
            v: v.into_boxed_slice(),
        };
        let t = TcpTransport::new(2, Some(k)).unwrap();
        t.send(1, padded.clone());
        let got = t
            .recv_timeout(1, Duration::from_secs(5))
            .expect("tcp delivery");
        // Lossless round-trip including the zero padding lanes.
        assert_eq!(got, padded);
        // The socket carried the K-strided frame (+ 4-byte length prefix
        // + the stream envelope), not the padded in-memory payload.
        assert_eq!(
            t.stats().bytes,
            (codec::padded_token_wire_size(&padded, k) + 4 + codec::envelope_overhead(false))
                as u64
        );
        t.shutdown();
    }

    #[test]
    fn bf16_ring_halves_factor_bytes_and_round_trips_exact_values() {
        // Two remote ranks on the bf16 wire. The payload values are all
        // bf16-representable (small sums of a few powers of two), so the
        // round-trip must be exact — and the socket must carry the bf16
        // frame, not the f32 one.
        let k = 5usize;
        let kp = crate::kernel::padded_k(k);
        let ncols = 2usize;
        let mut v = vec![0f32; ncols * kp];
        for bi in 0..ncols {
            for kk in 0..k {
                v[bi * kp + kk] = (bi * 10 + kk) as f32 + 0.5;
            }
        }
        let padded = Token {
            j: 3,
            iter: 1,
            phase: Phase::Update,
            visits: 0,
            w: Box::from([0.5f32, -1.0]),
            v: v.into_boxed_slice(),
        };
        let l0 = TcpListener::bind("127.0.0.1:0").unwrap();
        let l1 = TcpListener::bind("127.0.0.1:0").unwrap();
        let a0 = l0.local_addr().unwrap();
        let a1 = l1.local_addr().unwrap();
        let t0 = TcpTransport::remote(
            0,
            l0,
            vec![a0, a1],
            Some(k),
            WirePrecision::Bf16,
            Duration::from_secs(10),
            None,
            None,
        )
        .unwrap();
        let t1 = TcpTransport::remote(
            1,
            l1,
            vec![a0, a1],
            Some(k),
            WirePrecision::Bf16,
            Duration::from_secs(10),
            None,
            None,
        )
        .unwrap();
        t0.send(1, padded.clone());
        let got = t1
            .recv_timeout(1, Duration::from_secs(10))
            .expect("bf16 tcp delivery");
        assert_eq!(got, padded, "bf16-representable payload must survive");
        assert_eq!(
            t0.stats().bytes,
            (codec::token_wire_size_bf16(&padded, k) + 4 + codec::envelope_overhead(false)) as u64
        );
        assert!(
            codec::token_wire_size_bf16(&padded, k) < codec::padded_token_wire_size(&padded, k)
        );
        t0.shutdown();
        t1.shutdown();
    }

    #[test]
    fn remote_send_retries_until_listener_appears() {
        // Rank 0 sends to rank 1 before rank 1's listener exists: the
        // bounded-backoff connect must hold the token until it appears.
        let l0 = TcpListener::bind("127.0.0.1:0").unwrap();
        let a0 = l0.local_addr().unwrap();
        let a1 = {
            let placeholder = TcpListener::bind("127.0.0.1:0").unwrap();
            placeholder.local_addr().unwrap()
            // dropped: the port is free (but could in principle be raced
            // away by another process — see the rebind fallback below).
        };
        let t0 = TcpTransport::remote(
            0,
            l0,
            vec![a0, a1],
            None,
            WirePrecision::F32,
            Duration::from_secs(10),
            None,
            None,
        )
        .unwrap();
        let sender = std::thread::spawn(move || {
            t0.send(1, tok(9, 4));
            t0
        });
        std::thread::sleep(Duration::from_millis(300));
        let l1 = match TcpListener::bind(a1) {
            Ok(l) => l,
            Err(_) => {
                eprintln!("skipping: ephemeral port {a1} was rebound by another process");
                let t0 = sender.join().unwrap();
                t0.shutdown();
                return;
            }
        };
        let t1 = TcpTransport::remote(
            1,
            l1,
            vec![a0, a1],
            None,
            WirePrecision::F32,
            Duration::from_secs(10),
            None,
            None,
        )
        .unwrap();
        let got = t1
            .recv_timeout(1, Duration::from_secs(10))
            .expect("late-bound peer must still receive the token");
        assert_eq!(got.j, 9);
        let t0 = sender.join().unwrap();
        assert_eq!(t0.send_failures(), 0);

        // Self-sends short-circuit the socket entirely.
        let bytes_before = t1.stats().bytes;
        t1.send(1, tok(5, 2));
        assert_eq!(t1.recv_timeout(1, Duration::from_secs(5)).unwrap().j, 5);
        assert_eq!(t1.stats().bytes, bytes_before, "self-send must not serialize");

        t0.shutdown();
        t1.shutdown();
    }

    #[test]
    fn remote_connect_gives_up_after_deadline() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let a = l.local_addr().unwrap();
        let dead = {
            let tmp = TcpListener::bind("127.0.0.1:0").unwrap();
            let d = tmp.local_addr().unwrap();
            drop(tmp);
            d
        };
        let t = TcpTransport::remote(
            0,
            l,
            vec![a, dead],
            None,
            WirePrecision::F32,
            Duration::from_millis(120),
            None,
            None,
        )
        .unwrap();
        let start = Instant::now();
        t.send(1, tok(1, 2));
        assert!(
            start.elapsed() < Duration::from_secs(3),
            "send must give up once the connect deadline passes"
        );
        assert_eq!(t.send_failures(), 1);
        t.shutdown();
    }

    #[test]
    fn keyed_ring_delivers_and_chaos_faults_are_absorbed() {
        // Two keyed remote ranks; rank 0's chaos plan duplicates its
        // first ring frame and drops its second. The duplicate must be
        // deduped (delivered once) and the drop must surface as nothing
        // but a sequence gap.
        let key = Some(crate::cluster::auth::derive_key("ring-pw"));
        let l0 = TcpListener::bind("127.0.0.1:0").unwrap();
        let l1 = TcpListener::bind("127.0.0.1:0").unwrap();
        let a0 = l0.local_addr().unwrap();
        let a1 = l1.local_addr().unwrap();
        let plan = Arc::new(ChaosPlan::parse("dup:ring:0;drop:ring:1").unwrap());
        let t0 = TcpTransport::remote(
            0,
            l0,
            vec![a0, a1],
            None,
            WirePrecision::F32,
            Duration::from_secs(10),
            key,
            Some(plan),
        )
        .unwrap();
        let t1 = TcpTransport::remote(
            1,
            l1,
            vec![a0, a1],
            None,
            WirePrecision::F32,
            Duration::from_secs(10),
            key,
            None,
        )
        .unwrap();

        t0.send(1, tok(10, 2)); // duplicated on the wire, delivered once
        t0.send(1, tok(11, 2)); // dropped on the floor
        t0.send(1, tok(12, 2)); // delivered

        let first = t1.recv_timeout(1, Duration::from_secs(10)).expect("first");
        assert_eq!(first.j, 10);
        let second = t1.recv_timeout(1, Duration::from_secs(10)).expect("second");
        assert_eq!(second.j, 12, "dropped frame must not be delivered");
        assert!(
            t1.recv_timeout(1, Duration::from_millis(200)).is_none(),
            "the chaos duplicate leaked through dedup"
        );
        assert_eq!(t0.send_failures(), 0);
        t0.shutdown();
        t1.shutdown();
    }
}
