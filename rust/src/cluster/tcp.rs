//! Real TCP transport over the token codec (loopback multi-process mode).
//!
//! Each worker owns one listening socket; `send(dst, tok)` writes a
//! length-prefixed codec frame to a (lazily established, then cached)
//! connection to `dst`'s listener. A reader thread per accepted connection
//! pushes decoded tokens into the worker's local inbox.
//!
//! This is the transport the `--transport tcp` CLI mode uses; the engine
//! semantics are identical to [`super::LocalTransport`], only the medium
//! changes, which is exactly the property the Fig. 6 multi-machine
//! comparison needs.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use super::{codec, LocalTransport, Transport, TransportStats};
use crate::nomad::token::Token;

/// TCP loopback transport for `p` workers.
pub struct TcpTransport {
    inbox: LocalTransport,
    addrs: Vec<SocketAddr>,
    conns: Vec<Mutex<Option<TcpStream>>>,
    /// `Some(k)` when the engine circulates lane-padded token payloads:
    /// frames are stripped to the K-strided wire form on send and
    /// re-padded on receive, so the bytes on the socket are identical to
    /// the unpadded era. `None` = payloads are already K-strided.
    wire_k: Option<usize>,
    bytes: AtomicU64,
    messages: AtomicU64,
    down: Arc<AtomicBool>,
    accept_threads: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl TcpTransport {
    /// Binds `p` listeners on ephemeral loopback ports and starts acceptor
    /// threads that feed each worker's inbox. `wire_k` declares the
    /// circulating tokens' payload layout (see the field docs).
    pub fn new(p: usize, wire_k: Option<usize>) -> Result<Arc<Self>> {
        let mut listeners = Vec::with_capacity(p);
        let mut addrs = Vec::with_capacity(p);
        for _ in 0..p {
            let l = TcpListener::bind("127.0.0.1:0").context("bind loopback")?;
            addrs.push(l.local_addr()?);
            listeners.push(l);
        }
        let t = Arc::new(TcpTransport {
            inbox: LocalTransport::new(p),
            addrs,
            conns: (0..p).map(|_| Mutex::new(None)).collect(),
            wire_k,
            bytes: AtomicU64::new(0),
            messages: AtomicU64::new(0),
            down: Arc::new(AtomicBool::new(false)),
            accept_threads: Mutex::new(Vec::new()),
        });
        for (w, listener) in listeners.into_iter().enumerate() {
            let tt = Arc::clone(&t);
            let down = Arc::clone(&t.down);
            listener.set_nonblocking(true)?;
            let h = std::thread::Builder::new()
                .name(format!("tcp-accept-{w}"))
                .spawn(move || {
                    while !down.load(Ordering::Relaxed) {
                        match listener.accept() {
                            Ok((stream, _)) => {
                                stream.set_nodelay(true).ok();
                                let tt2 = Arc::clone(&tt);
                                let down2 = Arc::clone(&down);
                                std::thread::Builder::new()
                                    .name(format!("tcp-read-{w}"))
                                    .spawn(move || tt2.read_loop(w, stream, down2))
                                    .expect("spawn reader");
                            }
                            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                                std::thread::sleep(Duration::from_millis(2));
                            }
                            Err(_) => break,
                        }
                    }
                })
                .expect("spawn acceptor");
            t.accept_threads.lock().unwrap().push(h);
        }
        Ok(t)
    }

    fn read_loop(&self, worker: usize, mut stream: TcpStream, down: Arc<AtomicBool>) {
        stream
            .set_read_timeout(Some(Duration::from_millis(50)))
            .ok();
        let mut len_buf = [0u8; 4];
        let mut frame = Vec::new();
        while !down.load(Ordering::Relaxed) {
            match stream.read_exact(&mut len_buf) {
                Ok(()) => {}
                Err(ref e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    continue;
                }
                Err(_) => return,
            }
            let len = u32::from_le_bytes(len_buf) as usize;
            if len > 1 << 20 {
                return; // corrupt frame; drop the connection
            }
            frame.resize(len, 0);
            if read_fully(&mut stream, &mut frame, &down).is_err() {
                return;
            }
            let decoded = if self.wire_k.is_some() {
                codec::decode_token_padded(&frame)
            } else {
                codec::decode_token(&frame)
            };
            match decoded {
                Ok(tok) => self.inbox.send(worker, tok),
                Err(_) => return,
            }
        }
    }

    fn connect(&self, dst: usize) -> Result<TcpStream> {
        let s = TcpStream::connect(self.addrs[dst]).context("connect")?;
        s.set_nodelay(true).ok();
        Ok(s)
    }
}

/// read_exact that tolerates the read timeout while waiting mid-frame.
fn read_fully(stream: &mut TcpStream, buf: &mut [u8], down: &AtomicBool) -> std::io::Result<()> {
    let mut read = 0;
    while read < buf.len() {
        if down.load(Ordering::Relaxed) {
            return Err(std::io::ErrorKind::Interrupted.into());
        }
        match stream.read(&mut buf[read..]) {
            Ok(0) => return Err(std::io::ErrorKind::UnexpectedEof.into()),
            Ok(n) => read += n,
            Err(ref e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

impl Transport for TcpTransport {
    fn send(&self, dst: usize, tok: Token) {
        let mut frame = Vec::new();
        match self.wire_k {
            Some(k) => codec::encode_token_padded(&tok, k, &mut frame),
            None => codec::encode_token(&tok, &mut frame),
        }
        let mut msg = Vec::with_capacity(frame.len() + 4);
        msg.extend_from_slice(&(frame.len() as u32).to_le_bytes());
        msg.extend_from_slice(&frame);
        self.messages.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(msg.len() as u64, Ordering::Relaxed);

        let mut guard = self.conns[dst].lock().unwrap();
        if guard.is_none() {
            match self.connect(dst) {
                Ok(s) => *guard = Some(s),
                Err(_) => return, // shutdown race: drop silently
            }
        }
        if let Some(stream) = guard.as_mut() {
            if stream.write_all(&msg).is_err() {
                *guard = None;
            }
        }
    }

    fn recv_timeout(&self, worker: usize, timeout: Duration) -> Option<Token> {
        self.inbox.recv_timeout(worker, timeout)
    }

    fn shutdown(&self) {
        self.down.store(true, Ordering::SeqCst);
        for c in &self.conns {
            *c.lock().unwrap() = None;
        }
        let mut threads = self.accept_threads.lock().unwrap();
        for h in threads.drain(..) {
            let _ = h.join();
        }
    }

    fn stats(&self) -> TransportStats {
        TransportStats {
            messages: self.messages.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
        }
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nomad::token::Phase;

    fn tok(j: u32, k: usize) -> Token {
        Token {
            j,
            iter: 1,
            phase: Phase::Update,
            visits: 2,
            w: Box::from([0.5f32]),
            v: (0..k).map(|i| i as f32).collect(),
        }
    }

    #[test]
    fn tcp_roundtrip_between_workers() {
        let t = TcpTransport::new(2, None).unwrap();
        t.send(1, tok(42, 4));
        let got = t
            .recv_timeout(1, Duration::from_secs(5))
            .expect("tcp delivery");
        assert_eq!(got.j, 42);
        assert_eq!(got.v.len(), 4);
        assert!(t.stats().bytes > 0);
        t.shutdown();
    }

    #[test]
    fn tcp_many_messages_in_order() {
        let t = TcpTransport::new(3, None).unwrap();
        for j in 0..100 {
            t.send(2, tok(j, 8));
        }
        for j in 0..100 {
            let got = t.recv_timeout(2, Duration::from_secs(5)).expect("msg");
            assert_eq!(got.j, j);
        }
        t.shutdown();
    }

    #[test]
    fn tcp_padded_layout_survives_the_k_strided_wire() {
        let k = 5usize;
        let kp = crate::kernel::padded_k(k);
        let ncols = 2usize;
        let mut v = vec![0f32; ncols * kp];
        for bi in 0..ncols {
            for kk in 0..k {
                v[bi * kp + kk] = (bi * 10 + kk) as f32 + 0.5;
            }
        }
        let padded = Token {
            j: 3,
            iter: 1,
            phase: Phase::Update,
            visits: 0,
            w: Box::from([0.5f32, -1.0]),
            v: v.into_boxed_slice(),
        };
        let t = TcpTransport::new(2, Some(k)).unwrap();
        t.send(1, padded.clone());
        let got = t
            .recv_timeout(1, Duration::from_secs(5))
            .expect("tcp delivery");
        // Lossless round-trip including the zero padding lanes.
        assert_eq!(got, padded);
        // The socket carried the K-strided frame (+ 4-byte length prefix),
        // not the padded in-memory payload.
        assert_eq!(
            t.stats().bytes,
            (codec::padded_token_wire_size(&padded, k) + 4) as u64
        );
        t.shutdown();
    }
}
