//! Wire codec for parameter tokens.
//!
//! Layout (little-endian):
//! `magic u16 | j u32 | iter u32 | phase u8 | visits u16 | nw u32 | nv u32
//! | w[nw] f32 | v[nv] f32`
//!
//! Used by the simulated-network transport (to account bytes) and the TCP
//! transport (framed with a u32 length prefix).
//!
//! ## Padded in-memory layout vs the K-strided wire form
//!
//! The engine circulates tokens whose factor payload is **lane-padded**:
//! `v` is `ncols x kp` row-major with `kp = padded_k(k)` and zero padding
//! lanes (EXPERIMENTS.md §Perf). The wire format is deliberately
//! *unchanged* from the unpadded era: [`encode_token_padded`] strips each
//! row back to its K real entries (producing byte-identical frames to
//! [`encode_token`] on a K-strided token), and [`decode_token_padded`]
//! re-deals the wire rows into the padded layout (`k` is recovered as
//! `nv / nw`). [`encode_token`] / [`decode_token`] stay layout-agnostic:
//! they move `v` verbatim, which is also correct whenever `k` is already
//! a lane multiple.
//!
//! ## bf16 token wires
//!
//! With [`WirePrecision::Bf16`] the ring transport swaps in the bf16
//! body codec ([`encode_token_bf16`] / [`decode_token_bf16`]): the same
//! header with a distinct magic (`0xDB16`), and **both** the `w` and the
//! K-stripped `v` payloads carried as bfloat16 (`u16` LE) — the top 16
//! bits of the f32 pattern, converted with round-to-nearest-even
//! ([`f32_to_bf16`]). bf16 keeps f32's exponent range, so values map
//! exactly when bf16-representable (±0, ±inf included; NaNs stay NaN)
//! and within `2^-8` relative error otherwise. The halved payload applies
//! only to ring token hops: control frames, `FinalBlock` model frames and
//! block checkpoints stay f32. Both ends must agree on the precision —
//! the Join/Assign handshake enforces that (`cluster::runtime`).

//! ## Stream envelope
//!
//! Every length-prefixed frame on a cluster socket — control-plane
//! frames and ring tokens alike — is wrapped in a small envelope by
//! [`FrameSealer`] / [`FrameOpener`]:
//!
//! `magic u16 0xD5FC | flags u8 | seq u64 | [tag 32B if authed] | body`
//!
//! The per-connection sequence number lets the receiver drop exact
//! duplicates (chaos-injected or retransmitted) without delivering them
//! twice, and the optional HMAC-SHA256 tag (keyed from
//! `cluster_secret`, computed over `seq || body`) authenticates the
//! frame so stray or hostile traffic is rejected at the wire. Rejection
//! is counted and logged; the caller drops the connection.

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, ensure, Result};

use crate::cluster::auth::{hmac_sha256, tags_equal};
use crate::kernel::padded_k;
use crate::nomad::token::{Phase, Token};

const MAGIC: u16 = 0xD5FA;

/// Body magic of the bf16 token frame — distinct from the f32 token
/// (`0xD5FA`) so a precision-mismatched peer fails loudly at decode
/// instead of misparsing payload bytes.
const MAGIC_BF16: u16 = 0xDB16;

/// Precision of the ring token payloads on the wire. Negotiated at Join:
/// driver and workers must agree or the worker is rejected.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WirePrecision {
    /// Full f32 payloads (the default, bitwise-exact wire).
    #[default]
    F32,
    /// bfloat16 payloads: half the token bytes, `<= 2^-8` relative
    /// mantissa error per value, full f32 exponent range.
    Bf16,
}

impl WirePrecision {
    /// Stable lowercase name (config value, CLI flag value, logs).
    pub fn name(self) -> &'static str {
        match self {
            WirePrecision::F32 => "f32",
            WirePrecision::Bf16 => "bf16",
        }
    }

    /// Parses a config/CLI value.
    pub fn parse(s: &str) -> Result<WirePrecision> {
        match s {
            "f32" => Ok(WirePrecision::F32),
            "bf16" => Ok(WirePrecision::Bf16),
            other => bail!("wire_precision must be f32 or bf16, got {other:?}"),
        }
    }

    /// The single-byte wire tag (Join handshake field).
    pub fn to_byte(self) -> u8 {
        match self {
            WirePrecision::F32 => 0,
            WirePrecision::Bf16 => 1,
        }
    }

    /// Inverse of [`to_byte`](WirePrecision::to_byte).
    pub fn from_byte(b: u8) -> Result<WirePrecision> {
        match b {
            0 => Ok(WirePrecision::F32),
            1 => Ok(WirePrecision::Bf16),
            other => bail!("unknown wire_precision byte {other}"),
        }
    }
}

/// f32 → bfloat16 with round-to-nearest-even: the value whose top 16
/// bits survive is the nearest bf16, ties to even mantissa. NaN payloads
/// are truncated but never rounded (a NaN can not become Inf); a NaN
/// whose surviving mantissa bits would be zero gets the quiet bit forced
/// so it stays NaN.
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        let mut h = (bits >> 16) as u16;
        if h & 0x007f == 0 {
            h |= 0x0040;
        }
        return h;
    }
    // Round-to-nearest-even on the truncated 16 bits: add 0x7fff plus
    // the lowest surviving bit, then shift. Finite values that overflow
    // bf16's (identical) exponent range round to ±inf, exactly as IEEE
    // RNE prescribes.
    let round = 0x7fff + ((bits >> 16) & 1);
    ((bits.wrapping_add(round)) >> 16) as u16
}

/// bfloat16 → f32 (exact: bf16 values are a subset of f32).
pub fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Envelope magic, distinct from both the token (`0xD5FA`) and control
/// (`0xD5FB`) body magics so a peer speaking the pre-envelope protocol
/// is rejected loudly instead of misparsed.
pub const ENVELOPE_MAGIC: u16 = 0xD5FC;

const ENV_FLAG_AUTH: u8 = 1;

/// Envelope header: magic u16 | flags u8 | seq u64.
const ENV_HDR: usize = 2 + 1 + 8;

/// HMAC-SHA256 tag width.
pub const TAG_LEN: usize = 32;

/// Bytes the envelope adds on top of the body.
pub fn envelope_overhead(authed: bool) -> usize {
    ENV_HDR + if authed { TAG_LEN } else { 0 }
}

/// Seals outbound frames for one connection: stamps a monotone
/// per-connection sequence number and, when keyed, an HMAC-SHA256 tag
/// over `seq || body`.
pub struct FrameSealer {
    key: Option<[u8; 32]>,
    seq: AtomicU64,
}

impl FrameSealer {
    pub fn new(key: Option<[u8; 32]>) -> FrameSealer {
        FrameSealer {
            key,
            seq: AtomicU64::new(0),
        }
    }

    /// Bytes this sealer adds to every body.
    pub fn overhead(&self) -> usize {
        envelope_overhead(self.key.is_some())
    }

    /// Wraps `body` into `out` (cleared first), consuming one sequence
    /// number.
    pub fn seal(&self, body: &[u8], out: &mut Vec<u8>) {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        out.clear();
        out.reserve(self.overhead() + body.len());
        out.extend_from_slice(&ENVELOPE_MAGIC.to_le_bytes());
        out.push(if self.key.is_some() { ENV_FLAG_AUTH } else { 0 });
        out.extend_from_slice(&seq.to_le_bytes());
        if let Some(key) = &self.key {
            let tag = hmac_sha256(key, &[&seq.to_le_bytes(), body]);
            out.extend_from_slice(&tag);
        }
        out.extend_from_slice(body);
    }
}

/// What [`FrameOpener::open`] made of one inbound envelope.
#[derive(Debug, PartialEq, Eq)]
pub enum Opened<'a> {
    /// A fresh frame: deliver the body.
    Body(&'a [u8]),
    /// An exact retransmit (sequence number already seen): discard.
    Duplicate,
}

/// Validates inbound envelopes for one connection: magic, auth mode,
/// tag, and sequence ordering. An `Err` means the connection should be
/// dropped; the rejection has already been counted and logged.
pub struct FrameOpener {
    key: Option<[u8; 32]>,
    /// Highest sequence number accepted so far.
    last_seq: Option<u64>,
    rejected: u64,
    gaps: u64,
    /// Names the connection in rejection logs (e.g. "driver control").
    label: &'static str,
}

impl FrameOpener {
    pub fn new(key: Option<[u8; 32]>, label: &'static str) -> FrameOpener {
        FrameOpener {
            key,
            last_seq: None,
            rejected: 0,
            gaps: 0,
            label,
        }
    }

    /// Envelopes rejected on this connection (auth/format failures).
    pub fn rejected(&self) -> u64 {
        self.rejected
    }

    /// Sequence-number gaps observed (frames lost upstream of us).
    pub fn gaps(&self) -> u64 {
        self.gaps
    }

    fn reject(&mut self, why: String) -> anyhow::Error {
        self.rejected += 1;
        eprintln!(
            "dsfacto: rejecting frame on {} connection: {why} ({} rejected here)",
            self.label, self.rejected
        );
        anyhow::anyhow!("{why}")
    }

    /// Validates one envelope, returning the body (or `Duplicate` for a
    /// replayed sequence number).
    pub fn open<'a>(&mut self, envelope: &'a [u8]) -> Result<Opened<'a>> {
        if envelope.len() < ENV_HDR {
            return Err(self.reject(format!("envelope too short: {} bytes", envelope.len())));
        }
        let magic = u16::from_le_bytes([envelope[0], envelope[1]]);
        if magic != ENVELOPE_MAGIC {
            return Err(self.reject(format!("bad envelope magic {magic:#06x}")));
        }
        let flags = envelope[2];
        let authed = flags & ENV_FLAG_AUTH != 0;
        if flags & !ENV_FLAG_AUTH != 0 {
            return Err(self.reject(format!("unknown envelope flags {flags:#04x}")));
        }
        if authed != self.key.is_some() {
            return Err(self.reject(if authed {
                "authenticated frame but no cluster_secret configured here".to_string()
            } else {
                "unauthenticated frame on a secret-keyed connection".to_string()
            }));
        }
        let seq = u64::from_le_bytes(envelope[3..11].try_into().unwrap());
        let body = if let Some(key) = &self.key {
            if envelope.len() < ENV_HDR + TAG_LEN {
                return Err(self.reject("authenticated envelope missing its tag".to_string()));
            }
            let tag: &[u8; 32] = envelope[ENV_HDR..ENV_HDR + TAG_LEN].try_into().unwrap();
            let body = &envelope[ENV_HDR + TAG_LEN..];
            let want = hmac_sha256(key, &[&seq.to_le_bytes(), body]);
            if !tags_equal(tag, &want) {
                return Err(self.reject("HMAC tag mismatch".to_string()));
            }
            body
        } else {
            &envelope[ENV_HDR..]
        };
        match self.last_seq {
            Some(last) if seq <= last => return Ok(Opened::Duplicate),
            Some(last) => {
                if seq > last + 1 {
                    self.gaps += seq - last - 1;
                }
            }
            None => {
                if seq > 0 {
                    self.gaps += seq;
                }
            }
        }
        self.last_seq = Some(seq);
        Ok(Opened::Body(body))
    }
}

/// Fixed header size: magic u16 | j u32 | iter u32 | phase u8 |
/// visits u16 | nw u32 | nv u32.
const WIRE_HDR: usize = 2 + 4 + 4 + 1 + 2 + 4 + 4;

/// Serialized size of a token in bytes.
pub fn token_wire_size(tok: &Token) -> usize {
    WIRE_HDR + 4 * tok.w.len() + 4 * tok.v.len()
}

/// Serializes a token into `out` (cleared first).
pub fn encode_token(tok: &Token, out: &mut Vec<u8>) {
    out.clear();
    out.reserve(token_wire_size(tok));
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&tok.j.to_le_bytes());
    out.extend_from_slice(&tok.iter.to_le_bytes());
    out.push(match tok.phase {
        Phase::Update => 0,
        Phase::Recompute => 1,
    });
    out.extend_from_slice(&tok.visits.to_le_bytes());
    out.extend_from_slice(&(tok.w.len() as u32).to_le_bytes());
    out.extend_from_slice(&(tok.v.len() as u32).to_le_bytes());
    for &x in tok.w.iter() {
        out.extend_from_slice(&x.to_le_bytes());
    }
    for &x in tok.v.iter() {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Deserializes a token.
pub fn decode_token(buf: &[u8]) -> Result<Token> {
    const HDR: usize = WIRE_HDR;
    if buf.len() < HDR {
        bail!("token frame too short: {} bytes", buf.len());
    }
    let magic = u16::from_le_bytes([buf[0], buf[1]]);
    if magic != MAGIC {
        bail!("bad token magic {magic:#06x}");
    }
    let j = u32::from_le_bytes(buf[2..6].try_into().unwrap());
    let iter = u32::from_le_bytes(buf[6..10].try_into().unwrap());
    let phase = match buf[10] {
        0 => Phase::Update,
        1 => Phase::Recompute,
        other => bail!("bad phase byte {other}"),
    };
    let visits = u16::from_le_bytes([buf[11], buf[12]]);
    let nw = u32::from_le_bytes(buf[13..17].try_into().unwrap()) as usize;
    let nv = u32::from_le_bytes(buf[17..21].try_into().unwrap()) as usize;
    let need = HDR + 4 * (nw + nv);
    if buf.len() != need {
        bail!("token frame length {} != expected {need}", buf.len());
    }
    if nw > (1 << 24) || nv > (1 << 28) {
        bail!("token block implausibly large: nw={nw} nv={nv}");
    }
    let mut w = vec![0f32; nw].into_boxed_slice();
    for (i, chunk) in buf[HDR..HDR + 4 * nw].chunks_exact(4).enumerate() {
        w[i] = f32::from_le_bytes(chunk.try_into().unwrap());
    }
    let mut v = vec![0f32; nv].into_boxed_slice();
    for (i, chunk) in buf[HDR + 4 * nw..].chunks_exact(4).enumerate() {
        v[i] = f32::from_le_bytes(chunk.try_into().unwrap());
    }
    Ok(Token {
        j,
        iter,
        phase,
        visits,
        w,
        v,
    })
}

/// Wire size of a lane-padded in-memory token (`v` is `ncols x
/// padded_k(k)`): the K-strided frame it serializes to, identical to
/// [`token_wire_size`] of the unpadded twin.
pub fn padded_token_wire_size(tok: &Token, k: usize) -> usize {
    let kp = padded_k(k);
    let stripped = if kp == 0 { 0 } else { (tok.v.len() / kp) * k };
    WIRE_HDR + 4 * tok.w.len() + 4 * stripped
}

/// Serializes a lane-padded in-memory token (factor payload `ncols x
/// padded_k(k)`, zero padding) into the **K-strided** wire form: each
/// factor row is stripped to its `k` real entries, so the frame is
/// byte-identical to [`encode_token`] applied to the unpadded twin — the
/// wire format does not change with the in-memory layout.
pub fn encode_token_padded(tok: &Token, k: usize, out: &mut Vec<u8>) {
    let kp = padded_k(k);
    debug_assert_eq!(
        tok.v.len(),
        tok.ncols() * kp,
        "token payload is not {kp}-padded"
    );
    if kp == k || tok.v.is_empty() {
        // Already K-strided (k a lane multiple) or no factor payload
        // (bias token): the plain encoder is exact.
        encode_token(tok, out);
        return;
    }
    let ncols = tok.ncols();
    out.clear();
    out.reserve(padded_token_wire_size(tok, k));
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&tok.j.to_le_bytes());
    out.extend_from_slice(&tok.iter.to_le_bytes());
    out.push(match tok.phase {
        Phase::Update => 0,
        Phase::Recompute => 1,
    });
    out.extend_from_slice(&tok.visits.to_le_bytes());
    out.extend_from_slice(&(tok.w.len() as u32).to_le_bytes());
    out.extend_from_slice(&((ncols * k) as u32).to_le_bytes());
    for &x in tok.w.iter() {
        out.extend_from_slice(&x.to_le_bytes());
    }
    for bi in 0..ncols {
        for &x in &tok.vrow(bi, kp)[..k] {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }
}

/// Deserializes a K-strided wire frame into the engine's lane-padded
/// in-memory layout: `k` is recovered from the frame (`nv / nw`), and the
/// factor rows are re-dealt into `ncols x padded_k(k)` with zero padding.
/// Inverse of [`encode_token_padded`] (lossless round-trip, padding
/// included).
///
/// Deliberately composed over [`decode_token`] — the payload is copied a
/// second time into the padded buffer, but the frame validation lives in
/// exactly one place; the TCP receive path this serves is dominated by
/// socket I/O, not the extra `ncols x k` copy.
pub fn decode_token_padded(buf: &[u8]) -> Result<Token> {
    let tok = decode_token(buf)?;
    if tok.v.is_empty() {
        return Ok(tok);
    }
    let ncols = tok.w.len();
    ensure!(
        ncols > 0 && tok.v.len() % ncols == 0,
        "cannot infer factor width: nv={} nw={ncols}",
        tok.v.len()
    );
    let k = tok.v.len() / ncols;
    let kp = padded_k(k);
    if kp == k {
        return Ok(tok);
    }
    let mut v = vec![0f32; ncols * kp].into_boxed_slice();
    for bi in 0..ncols {
        v[bi * kp..bi * kp + k].copy_from_slice(&tok.v[bi * k..(bi + 1) * k]);
    }
    Ok(Token { v, ..tok })
}

/// Wire size of a lane-padded in-memory token under the bf16 codec: the
/// same header, both payloads at 2 bytes per value (the factor rows
/// K-stripped first, as in [`padded_token_wire_size`]).
pub fn token_wire_size_bf16(tok: &Token, k: usize) -> usize {
    let kp = padded_k(k);
    let stripped = if kp == 0 { 0 } else { (tok.v.len() / kp) * k };
    WIRE_HDR + 2 * tok.w.len() + 2 * stripped
}

/// Serializes a lane-padded in-memory token into the **bf16** wire form:
/// the [`encode_token_padded`] frame with magic `0xDB16` and every `w` /
/// K-stripped `v` value converted to bfloat16 (`u16` LE). Lossy by
/// design (see the module docs for the error contract); the `nw`/`nv`
/// counts still count *values*, not bytes.
pub fn encode_token_bf16(tok: &Token, k: usize, out: &mut Vec<u8>) {
    let kp = padded_k(k);
    debug_assert_eq!(
        tok.v.len(),
        tok.ncols() * kp,
        "token payload is not {kp}-padded"
    );
    let ncols = tok.ncols();
    out.clear();
    out.reserve(token_wire_size_bf16(tok, k));
    out.extend_from_slice(&MAGIC_BF16.to_le_bytes());
    out.extend_from_slice(&tok.j.to_le_bytes());
    out.extend_from_slice(&tok.iter.to_le_bytes());
    out.push(match tok.phase {
        Phase::Update => 0,
        Phase::Recompute => 1,
    });
    out.extend_from_slice(&tok.visits.to_le_bytes());
    out.extend_from_slice(&(tok.w.len() as u32).to_le_bytes());
    out.extend_from_slice(&((ncols * k) as u32).to_le_bytes());
    for &x in tok.w.iter() {
        out.extend_from_slice(&f32_to_bf16(x).to_le_bytes());
    }
    if !tok.v.is_empty() {
        for bi in 0..ncols {
            for &x in &tok.vrow(bi, kp)[..k] {
                out.extend_from_slice(&f32_to_bf16(x).to_le_bytes());
            }
        }
    }
}

/// Deserializes a bf16 wire frame into the lane-padded in-memory layout
/// (widening every value back to f32; `k` recovered as `nv / nw`, rows
/// re-dealt to `padded_k(k)` stride with zero padding lanes). Inverse of
/// [`encode_token_bf16`] up to the bf16 quantization applied on encode —
/// a decoded token re-encodes to the identical frame.
pub fn decode_token_bf16(buf: &[u8]) -> Result<Token> {
    const HDR: usize = WIRE_HDR;
    if buf.len() < HDR {
        bail!("bf16 token frame too short: {} bytes", buf.len());
    }
    let magic = u16::from_le_bytes([buf[0], buf[1]]);
    if magic != MAGIC_BF16 {
        bail!("bad bf16 token magic {magic:#06x} (precision mismatch with the sender?)");
    }
    let j = u32::from_le_bytes(buf[2..6].try_into().unwrap());
    let iter = u32::from_le_bytes(buf[6..10].try_into().unwrap());
    let phase = match buf[10] {
        0 => Phase::Update,
        1 => Phase::Recompute,
        other => bail!("bad phase byte {other}"),
    };
    let visits = u16::from_le_bytes([buf[11], buf[12]]);
    let nw = u32::from_le_bytes(buf[13..17].try_into().unwrap()) as usize;
    let nv = u32::from_le_bytes(buf[17..21].try_into().unwrap()) as usize;
    if nw > (1 << 24) || nv > (1 << 28) {
        bail!("token block implausibly large: nw={nw} nv={nv}");
    }
    let need = HDR + 2 * (nw + nv);
    if buf.len() != need {
        bail!("bf16 token frame length {} != expected {need}", buf.len());
    }
    let mut w = vec![0f32; nw].into_boxed_slice();
    for (i, chunk) in buf[HDR..HDR + 2 * nw].chunks_exact(2).enumerate() {
        w[i] = bf16_to_f32(u16::from_le_bytes(chunk.try_into().unwrap()));
    }
    if nv == 0 {
        return Ok(Token {
            j,
            iter,
            phase,
            visits,
            w,
            v: Box::from([]),
        });
    }
    ensure!(nw > 0 && nv % nw == 0, "cannot infer factor width: nv={nv} nw={nw}");
    let k = nv / nw;
    let kp = padded_k(k);
    let mut v = vec![0f32; nw * kp].into_boxed_slice();
    for (i, chunk) in buf[HDR + 2 * nw..].chunks_exact(2).enumerate() {
        let (bi, kk) = (i / k, i % k);
        v[bi * kp + kk] = bf16_to_f32(u16::from_le_bytes(chunk.try_into().unwrap()));
    }
    Ok(Token {
        j,
        iter,
        phase,
        visits,
        w,
        v,
    })
}

/// Shared little-endian framing helpers for every body codec in the
/// crate that speaks the `len u32 | magic u16 | kind u8 | fields` wire
/// discipline (the control plane's `0xD5FB` frames and the scoring
/// server's `0xD5FE` frames). Writers append to a `Vec<u8>`; the
/// [`Reader`](wire::Reader) is a bounds-checked cursor whose
/// [`finish`](wire::Reader::finish) rejects trailing bytes, so every
/// decoder gets truncation *and* extension rejection from the same code.
pub(crate) mod wire {
    use anyhow::{ensure, Context, Result};

    pub(crate) fn put_u8(out: &mut Vec<u8>, x: u8) {
        out.push(x);
    }

    pub(crate) fn put_u16(out: &mut Vec<u8>, x: u16) {
        out.extend_from_slice(&x.to_le_bytes());
    }

    pub(crate) fn put_u32(out: &mut Vec<u8>, x: u32) {
        out.extend_from_slice(&x.to_le_bytes());
    }

    pub(crate) fn put_u64(out: &mut Vec<u8>, x: u64) {
        out.extend_from_slice(&x.to_le_bytes());
    }

    pub(crate) fn put_f32(out: &mut Vec<u8>, x: f32) {
        out.extend_from_slice(&x.to_le_bytes());
    }

    pub(crate) fn put_f64(out: &mut Vec<u8>, x: f64) {
        out.extend_from_slice(&x.to_le_bytes());
    }

    pub(crate) fn put_bytes(out: &mut Vec<u8>, bytes: &[u8]) {
        put_u32(out, bytes.len() as u32);
        out.extend_from_slice(bytes);
    }

    pub(crate) fn put_str(out: &mut Vec<u8>, s: &str) {
        put_bytes(out, s.as_bytes());
    }

    /// Bounds-checked cursor over a frame body.
    #[derive(Clone)]
    pub(crate) struct Reader<'a> {
        buf: &'a [u8],
        pos: usize,
    }

    impl<'a> Reader<'a> {
        pub(crate) fn new(buf: &'a [u8]) -> Reader<'a> {
            Reader { buf, pos: 0 }
        }

        pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8]> {
            ensure!(
                n <= self.buf.len() - self.pos,
                "frame truncated at byte {}",
                self.pos
            );
            let s = &self.buf[self.pos..self.pos + n];
            self.pos += n;
            Ok(s)
        }

        pub(crate) fn u8(&mut self) -> Result<u8> {
            Ok(self.take(1)?[0])
        }

        pub(crate) fn u16(&mut self) -> Result<u16> {
            Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
        }

        pub(crate) fn u32(&mut self) -> Result<u32> {
            Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
        }

        pub(crate) fn u64(&mut self) -> Result<u64> {
            Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
        }

        pub(crate) fn f32(&mut self) -> Result<f32> {
            Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
        }

        pub(crate) fn f64(&mut self) -> Result<f64> {
            Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
        }

        /// A u32-length-prefixed byte blob, capped at `max` bytes (each
        /// protocol passes its own frame bound).
        pub(crate) fn bytes(&mut self, max: usize) -> Result<Vec<u8>> {
            let n = self.u32()? as usize;
            ensure!(n <= max, "embedded blob too large: {n} bytes");
            Ok(self.take(n)?.to_vec())
        }

        pub(crate) fn string(&mut self, max: usize) -> Result<String> {
            String::from_utf8(self.bytes(max)?).context("frame string is not UTF-8")
        }

        pub(crate) fn finish(&self) -> Result<()> {
            ensure!(
                self.pos == self.buf.len(),
                "frame has {} trailing bytes",
                self.buf.len() - self.pos
            );
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall_res;

    fn sample(k: usize) -> Token {
        Token {
            j: 12345,
            iter: 9,
            phase: Phase::Recompute,
            visits: 3,
            w: Box::from([-0.75f32, 0.5]),
            v: (0..2 * k).map(|i| i as f32 * 0.5).collect(),
        }
    }

    #[test]
    fn roundtrip() {
        let tok = sample(8);
        let mut buf = Vec::new();
        encode_token(&tok, &mut buf);
        assert_eq!(buf.len(), token_wire_size(&tok));
        let back = decode_token(&buf).unwrap();
        assert_eq!(back, tok);
    }

    #[test]
    fn rejects_garbage() {
        assert!(decode_token(&[]).is_err());
        assert!(decode_token(&[0u8; 21]).is_err()); // bad magic
        let tok = sample(2);
        let mut buf = Vec::new();
        encode_token(&tok, &mut buf);
        buf.truncate(buf.len() - 1);
        assert!(decode_token(&buf).is_err());
        let mut buf2 = Vec::new();
        encode_token(&tok, &mut buf2);
        buf2[10] = 9; // bad phase
        assert!(decode_token(&buf2).is_err());
    }

    #[test]
    fn padded_encode_is_byte_identical_to_stripped_plain_encode() {
        for k in [1usize, 3, 7, 8, 9, 16] {
            let kp = padded_k(k);
            let ncols = 3;
            let mut v_pad = vec![0f32; ncols * kp];
            let mut v_flat = vec![0f32; ncols * k];
            for bi in 0..ncols {
                for kk in 0..k {
                    let x = (bi * 31 + kk) as f32 * 0.25 - 1.0;
                    v_pad[bi * kp + kk] = x;
                    v_flat[bi * k + kk] = x;
                }
            }
            let padded = Token {
                j: 7,
                iter: 2,
                phase: Phase::Update,
                visits: 1,
                w: Box::from([0.5f32, -1.0, 2.0]),
                v: v_pad.into_boxed_slice(),
            };
            let stripped = Token {
                v: v_flat.into_boxed_slice(),
                ..padded.clone()
            };
            let mut a = Vec::new();
            encode_token_padded(&padded, k, &mut a);
            let mut b = Vec::new();
            encode_token(&stripped, &mut b);
            assert_eq!(a, b, "k={k}: wire bytes changed");
            assert_eq!(a.len(), padded_token_wire_size(&padded, k), "k={k}");
            assert_eq!(a.len(), token_wire_size(&stripped), "k={k}");
            // Lossless both ways.
            assert_eq!(decode_token_padded(&a).unwrap(), padded, "k={k}");
            assert_eq!(decode_token(&a).unwrap(), stripped, "k={k}");
        }
    }

    #[test]
    fn padded_codec_passes_bias_tokens_through() {
        let bias = Token {
            j: crate::nomad::token::BIAS,
            iter: 5,
            phase: Phase::Recompute,
            visits: 2,
            w: Box::from([0.75f32]),
            v: Box::from([]),
        };
        let mut a = Vec::new();
        encode_token_padded(&bias, 7, &mut a);
        let mut b = Vec::new();
        encode_token(&bias, &mut b);
        assert_eq!(a, b);
        assert_eq!(decode_token_padded(&a).unwrap(), bias);
    }

    #[test]
    fn envelope_roundtrips_unauth_and_authed() {
        for key in [None, Some(crate::cluster::auth::derive_key("s3cret"))] {
            let sealer = FrameSealer::new(key);
            let mut opener = FrameOpener::new(key, "test");
            for i in 0u8..4 {
                let body = vec![i; 5 + i as usize];
                let mut env = Vec::new();
                sealer.seal(&body, &mut env);
                assert_eq!(env.len(), body.len() + sealer.overhead());
                assert_eq!(opener.open(&env).unwrap(), Opened::Body(&body[..]));
            }
            assert_eq!(opener.rejected(), 0);
            assert_eq!(opener.gaps(), 0);
        }
    }

    #[test]
    fn envelope_drops_exact_duplicates_and_counts_gaps() {
        let sealer = FrameSealer::new(None);
        let mut opener = FrameOpener::new(None, "test");
        let mut frames = Vec::new();
        for i in 0u8..4 {
            let mut env = Vec::new();
            sealer.seal(&[i], &mut env);
            frames.push(env);
        }
        assert_eq!(opener.open(&frames[0]).unwrap(), Opened::Body(&[0][..]));
        // Replay of seq 0: dropped, not delivered, not a rejection.
        assert_eq!(opener.open(&frames[0]).unwrap(), Opened::Duplicate);
        // Frame 1 lost in transit; frame 2 arrives → one gap, delivered.
        assert_eq!(opener.open(&frames[2]).unwrap(), Opened::Body(&[2][..]));
        assert_eq!(opener.gaps(), 1);
        // Late arrival of the lost frame counts as a duplicate (seq < last).
        assert_eq!(opener.open(&frames[1]).unwrap(), Opened::Duplicate);
        assert_eq!(opener.open(&frames[3]).unwrap(), Opened::Body(&[3][..]));
        assert_eq!(opener.rejected(), 0);
    }

    #[test]
    fn envelope_rejects_tampering_wrong_keys_and_mode_mismatch() {
        let key_a = crate::cluster::auth::derive_key("alpha");
        let key_b = crate::cluster::auth::derive_key("beta");
        let sealer = FrameSealer::new(Some(key_a));
        let mut env = Vec::new();
        sealer.seal(b"payload", &mut env);

        // Tag verifies with the right key...
        let mut ok = FrameOpener::new(Some(key_a), "test");
        assert!(matches!(ok.open(&env).unwrap(), Opened::Body(b"payload")));
        // ...fails with the wrong key,
        let mut wrong = FrameOpener::new(Some(key_b), "test");
        assert!(wrong.open(&env).is_err());
        assert_eq!(wrong.rejected(), 1);
        // ...fails when the body is flipped,
        let mut tampered = env.clone();
        *tampered.last_mut().unwrap() ^= 0xff;
        let mut o = FrameOpener::new(Some(key_a), "test");
        assert!(o.open(&tampered).is_err());
        // ...and an authed frame is refused by an unkeyed opener (and
        // vice versa).
        let mut unkeyed = FrameOpener::new(None, "test");
        assert!(unkeyed.open(&env).is_err());
        let plain_sealer = FrameSealer::new(None);
        let mut plain = Vec::new();
        plain_sealer.seal(b"payload", &mut plain);
        let mut keyed = FrameOpener::new(Some(key_a), "test");
        assert!(keyed.open(&plain).is_err());
        assert_eq!(keyed.rejected(), 1);
    }

    #[test]
    fn envelope_rejects_garbage() {
        let mut opener = FrameOpener::new(None, "test");
        assert!(opener.open(&[]).is_err());
        assert!(opener.open(&[0u8; 11]).is_err()); // bad magic
        let mut env = Vec::new();
        FrameSealer::new(None).seal(b"x", &mut env);
        env[2] = 0x80; // unknown flag bit
        assert!(opener.open(&env).is_err());
        assert_eq!(opener.rejected(), 3);
    }

    #[test]
    fn wire_precision_parses_names_and_bytes() {
        for p in [WirePrecision::F32, WirePrecision::Bf16] {
            assert_eq!(WirePrecision::parse(p.name()).unwrap(), p);
            assert_eq!(WirePrecision::from_byte(p.to_byte()).unwrap(), p);
        }
        assert_eq!(WirePrecision::default(), WirePrecision::F32);
        assert!(WirePrecision::parse("f16").is_err());
        assert!(WirePrecision::from_byte(7).is_err());
    }

    #[test]
    fn bf16_is_exact_for_representable_values() {
        // Anything whose f32 bits have a zero low half is a bf16 value
        // and must survive the round-trip bit-for-bit.
        for x in [
            0.0f32,
            -0.0,
            1.0,
            -1.0,
            0.5,
            -2.0,
            1.984375, // 0x3FFE0000: all 7 explicit bf16 mantissa bits set
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::from_bits(0x0080_0000), // smallest normal
            f32::from_bits(0x7f7f_0000), // largest bf16 finite
        ] {
            let back = bf16_to_f32(f32_to_bf16(x));
            assert_eq!(back.to_bits(), x.to_bits(), "{x} not preserved");
        }
        // NaN stays NaN and keeps its surviving payload bits; a NaN whose
        // top mantissa bits are all zero must not collapse to Inf.
        let quiet = f32::from_bits(0x7fc1_2345);
        let h = f32_to_bf16(quiet);
        assert_eq!(h, 0x7fc1);
        assert!(bf16_to_f32(h).is_nan());
        let low_payload_nan = f32::from_bits(0x7f80_0001);
        assert!(bf16_to_f32(f32_to_bf16(low_payload_nan)).is_nan());
        let neg_nan = f32::from_bits(0xff80_0001);
        assert!(bf16_to_f32(f32_to_bf16(neg_nan)).is_nan());
    }

    #[test]
    fn bf16_rounds_to_nearest_even_within_2pow8() {
        // Exactly halfway between two bf16 values: ties go to the even
        // mantissa in both directions.
        assert_eq!(f32_to_bf16(f32::from_bits(0x3f80_8000)), 0x3f80);
        assert_eq!(f32_to_bf16(f32::from_bits(0x3f81_8000)), 0x3f82);
        // Just past halfway rounds up; just short truncates.
        assert_eq!(f32_to_bf16(f32::from_bits(0x3f80_8001)), 0x3f81);
        assert_eq!(f32_to_bf16(f32::from_bits(0x3f80_7fff)), 0x3f80);
        // f32::MAX overflows bf16's last finite step and rounds to inf.
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::MAX)), f32::INFINITY);
        // Relative error bound for normal values: half a bf16 ulp.
        let mut rng = crate::util::rng::Pcg64::seeded(41);
        for _ in 0..2000 {
            let x = rng.normal32(0.0, 100.0);
            let back = bf16_to_f32(f32_to_bf16(x));
            let rel = (back - x).abs() / x.abs().max(f32::MIN_POSITIVE);
            assert!(rel <= 1.0 / 256.0, "{x} -> {back}: rel err {rel}");
        }
    }

    #[test]
    fn bf16_token_roundtrip_and_size() {
        for k in [1usize, 3, 7, 8, 9, 16] {
            let kp = padded_k(k);
            let ncols = 3;
            let mut v_pad = vec![0f32; ncols * kp];
            for bi in 0..ncols {
                for kk in 0..k {
                    v_pad[bi * kp + kk] = (bi * 31 + kk) as f32 * 0.25 - 1.0;
                }
            }
            let tok = Token {
                j: 7,
                iter: 2,
                phase: Phase::Update,
                visits: 1,
                w: Box::from([0.5f32, -1.0, 2.0]),
                v: v_pad.into_boxed_slice(),
            };
            let mut buf = Vec::new();
            encode_token_bf16(&tok, k, &mut buf);
            assert_eq!(buf.len(), token_wire_size_bf16(&tok, k), "k={k}");
            let back = decode_token_bf16(&buf).unwrap();
            assert_eq!((back.j, back.iter, back.phase, back.visits), (7, 2, Phase::Update, 1));
            assert_eq!(back.v.len(), tok.v.len(), "k={k}: padded shape");
            for (i, (&got, &want)) in back.w.iter().zip(tok.w.iter()).enumerate() {
                assert_eq!(got, bf16_to_f32(f32_to_bf16(want)), "k={k} w[{i}]");
            }
            for (i, (&got, &want)) in back.v.iter().zip(tok.v.iter()).enumerate() {
                assert_eq!(got, bf16_to_f32(f32_to_bf16(want)), "k={k} v[{i}]");
            }
            // Idempotent once quantized: decode -> encode is identical.
            let mut buf2 = Vec::new();
            encode_token_bf16(&back, k, &mut buf2);
            assert_eq!(buf, buf2, "k={k}: re-encode changed bytes");
        }
    }

    #[test]
    fn bf16_codec_passes_bias_tokens_and_rejects_mismatched_magic() {
        let bias = Token {
            j: crate::nomad::token::BIAS,
            iter: 5,
            phase: Phase::Recompute,
            visits: 2,
            w: Box::from([0.75f32]),
            v: Box::from([]),
        };
        let mut b16 = Vec::new();
        encode_token_bf16(&bias, 7, &mut b16);
        let back = decode_token_bf16(&b16).unwrap();
        assert_eq!(back, bias, "0.75 is bf16-representable");

        // A precision-mismatched peer fails loudly, not silently.
        let mut f32_frame = Vec::new();
        encode_token_padded(&bias, 7, &mut f32_frame);
        assert!(decode_token_bf16(&f32_frame).is_err());
        assert!(decode_token(&b16).is_err());
        assert!(decode_token_bf16(&[]).is_err());
        let mut short = b16.clone();
        short.truncate(short.len() - 1);
        assert!(decode_token_bf16(&short).is_err());
    }

    #[test]
    fn bf16_wire_is_at_most_055x_f32() {
        // The realsim-like cluster shape (d=20958, k=16, c=40): the bench
        // records absolute bytes; this pins the ratio contract.
        let k = 16;
        let kp = padded_k(k);
        let ncols = 40;
        let tok = Token {
            j: 1,
            iter: 0,
            phase: Phase::Update,
            visits: 0,
            w: vec![0.1f32; ncols].into_boxed_slice(),
            v: vec![0.2f32; ncols * kp].into_boxed_slice(),
        };
        let f32_bytes = padded_token_wire_size(&tok, k) as f64;
        let bf16_bytes = token_wire_size_bf16(&tok, k) as f64;
        assert!(
            bf16_bytes <= 0.55 * f32_bytes,
            "bf16 {bf16_bytes} vs f32 {f32_bytes}"
        );
    }

    #[test]
    fn prop_roundtrip_random_tokens() {
        forall_res(
            "token codec roundtrip",
            64,
            |rng| {
                let ncols = 1 + rng.below_usize(8);
                let k = rng.below_usize(9);
                Token {
                    j: rng.next_u32(),
                    iter: rng.next_u32() % 1000,
                    phase: if rng.chance(0.5) {
                        Phase::Update
                    } else {
                        Phase::Recompute
                    },
                    visits: (rng.next_u32() % 64) as u16,
                    w: (0..ncols).map(|_| rng.normal32(0.0, 10.0)).collect(),
                    v: (0..ncols * k).map(|_| rng.normal32(0.0, 1.0)).collect(),
                }
            },
            |tok| {
                let mut buf = Vec::new();
                encode_token(tok, &mut buf);
                let back = decode_token(&buf).map_err(|e| e.to_string())?;
                if back == *tok {
                    Ok(())
                } else {
                    Err(format!("{back:?} != {tok:?}"))
                }
            },
        );
    }
}
