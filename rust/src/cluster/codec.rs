//! Wire codec for parameter tokens.
//!
//! Layout (little-endian):
//! `magic u16 | j u32 | iter u32 | phase u8 | visits u16 | k u16 | w f32 | v[k] f32`
//!
//! Used by the simulated-network transport (to account bytes) and the TCP
//! transport (framed with a u32 length prefix).

use anyhow::{bail, Result};

use crate::nomad::token::{Phase, Token};

const MAGIC: u16 = 0xD5FA;

/// Serialized size of a token in bytes.
pub fn token_wire_size(tok: &Token) -> usize {
    2 + 4 + 4 + 1 + 2 + 4 + 4 + 4 * tok.w.len() + 4 * tok.v.len()
}

/// Serializes a token into `out` (cleared first).
pub fn encode_token(tok: &Token, out: &mut Vec<u8>) {
    out.clear();
    out.reserve(token_wire_size(tok));
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&tok.j.to_le_bytes());
    out.extend_from_slice(&tok.iter.to_le_bytes());
    out.push(match tok.phase {
        Phase::Update => 0,
        Phase::Recompute => 1,
    });
    out.extend_from_slice(&tok.visits.to_le_bytes());
    out.extend_from_slice(&(tok.w.len() as u32).to_le_bytes());
    out.extend_from_slice(&(tok.v.len() as u32).to_le_bytes());
    for &x in tok.w.iter() {
        out.extend_from_slice(&x.to_le_bytes());
    }
    for &x in tok.v.iter() {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Deserializes a token.
pub fn decode_token(buf: &[u8]) -> Result<Token> {
    const HDR: usize = 21;
    if buf.len() < HDR {
        bail!("token frame too short: {} bytes", buf.len());
    }
    let magic = u16::from_le_bytes([buf[0], buf[1]]);
    if magic != MAGIC {
        bail!("bad token magic {magic:#06x}");
    }
    let j = u32::from_le_bytes(buf[2..6].try_into().unwrap());
    let iter = u32::from_le_bytes(buf[6..10].try_into().unwrap());
    let phase = match buf[10] {
        0 => Phase::Update,
        1 => Phase::Recompute,
        other => bail!("bad phase byte {other}"),
    };
    let visits = u16::from_le_bytes([buf[11], buf[12]]);
    let nw = u32::from_le_bytes(buf[13..17].try_into().unwrap()) as usize;
    let nv = u32::from_le_bytes(buf[17..21].try_into().unwrap()) as usize;
    let need = HDR + 4 * (nw + nv);
    if buf.len() != need {
        bail!("token frame length {} != expected {need}", buf.len());
    }
    if nw > (1 << 24) || nv > (1 << 28) {
        bail!("token block implausibly large: nw={nw} nv={nv}");
    }
    let mut w = vec![0f32; nw].into_boxed_slice();
    for (i, chunk) in buf[HDR..HDR + 4 * nw].chunks_exact(4).enumerate() {
        w[i] = f32::from_le_bytes(chunk.try_into().unwrap());
    }
    let mut v = vec![0f32; nv].into_boxed_slice();
    for (i, chunk) in buf[HDR + 4 * nw..].chunks_exact(4).enumerate() {
        v[i] = f32::from_le_bytes(chunk.try_into().unwrap());
    }
    Ok(Token {
        j,
        iter,
        phase,
        visits,
        w,
        v,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall_res;

    fn sample(k: usize) -> Token {
        Token {
            j: 12345,
            iter: 9,
            phase: Phase::Recompute,
            visits: 3,
            w: Box::from([-0.75f32, 0.5]),
            v: (0..2 * k).map(|i| i as f32 * 0.5).collect(),
        }
    }

    #[test]
    fn roundtrip() {
        let tok = sample(8);
        let mut buf = Vec::new();
        encode_token(&tok, &mut buf);
        assert_eq!(buf.len(), token_wire_size(&tok));
        let back = decode_token(&buf).unwrap();
        assert_eq!(back, tok);
    }

    #[test]
    fn rejects_garbage() {
        assert!(decode_token(&[]).is_err());
        assert!(decode_token(&[0u8; 21]).is_err()); // bad magic
        let tok = sample(2);
        let mut buf = Vec::new();
        encode_token(&tok, &mut buf);
        buf.truncate(buf.len() - 1);
        assert!(decode_token(&buf).is_err());
        let mut buf2 = Vec::new();
        encode_token(&tok, &mut buf2);
        buf2[10] = 9; // bad phase
        assert!(decode_token(&buf2).is_err());
    }

    #[test]
    fn prop_roundtrip_random_tokens() {
        forall_res(
            "token codec roundtrip",
            64,
            |rng| {
                let ncols = 1 + rng.below_usize(8);
                let k = rng.below_usize(9);
                Token {
                    j: rng.next_u32(),
                    iter: rng.next_u32() % 1000,
                    phase: if rng.chance(0.5) {
                        Phase::Update
                    } else {
                        Phase::Recompute
                    },
                    visits: (rng.next_u32() % 64) as u16,
                    w: (0..ncols).map(|_| rng.normal32(0.0, 10.0)).collect(),
                    v: (0..ncols * k).map(|_| rng.normal32(0.0, 1.0)).collect(),
                }
            },
            |tok| {
                let mut buf = Vec::new();
                encode_token(tok, &mut buf);
                let back = decode_token(&buf).map_err(|e| e.to_string())?;
                if back == *tok {
                    Ok(())
                } else {
                    Err(format!("{back:?} != {tok:?}"))
                }
            },
        );
    }
}
