//! First-class doubly-separable partition plans.
//!
//! Double separability — partitioning the data *and* the model at the
//! same time — is the structural idea of DS-FACTO (paper §4, Algorithm
//! 1). Before this module, the (row-shard x column-block) grid existed
//! only as three ad-hoc reimplementations inside the NOMAD engine, DSGD
//! and bulk-sync. Here it is a value:
//!
//! * [`RowPartition`] — which rows belong to which worker, with two
//!   strategies: [`RowStrategy::Contiguous`] (equal row counts, the
//!   legacy default — bitwise identical to the old hand-rolled chunking)
//!   and [`RowStrategy::NnzBalanced`] (greedy prefix split equalizing
//!   per-shard nnz on row-skewed data, never worse than contiguous).
//! * [`ColPartition`] — the column-block side: one `block_range`
//!   implementation behind the engine's token blocks and DSGD's column
//!   bounds, plus the [`auto_block_cols`] granularity heuristic.
//! * [`GridPlan`] — the composed grid and DSGD's block-diagonal stratum
//!   schedule `(shard + sub) % blocks`.
//! * [`Shard`] / [`build_shards_from_source`] — the materialized
//!   per-worker view (local CSR + CSC + labels + lane-blocked arenas),
//!   built through the [`crate::data::DataSource`] seam by a worker pool
//!   capped at `available_parallelism`: the in-memory source reproduces
//!   the legacy `slice_rows(..).to_csc()` build bit for bit
//!   ([`build_shards`] is that convenience), while a
//!   [`crate::data::ShardCacheSource`] reads each worker's shard file
//!   from disk so no step materializes the full CSR.
//! * [`PartitionStats`] — per-shard nnz and the max/mean imbalance ratio,
//!   surfaced through `EngineStats` and `Trainer::partition_stats`.
//!
//! The strategy is a config key (`row_partition = contiguous|balanced`)
//! wired through `ExperimentConfig` and `TrainerKind::build`.

// Hot-path-adjacent module: lint-clean regardless of the workflow-level
// gate (CI's hotpath-lint clippy job covers the whole library).
#![deny(clippy::all)]

mod plan;
mod shard;

pub use plan::{auto_block_cols, ColPartition, GridPlan, PartitionStats, RowPartition, RowStrategy};
pub use shard::{build_shards, build_shards_from_source, Shard, ShardArenas};
