//! Materialized per-worker shard views.
//!
//! A [`Shard`] is what a distributed worker actually touches: the local
//! row-block CSR (a [`Csr::slice_rows`] of the training set — or the
//! equivalent slice read from a shard-cache file), its CSC transpose (the
//! doubly-separable column access path of paper Figs. 1-2), the matching
//! label slice and the task. Construction goes through the
//! [`DataSource`] seam: [`Shard::from_source`] materializes one shard,
//! and [`build_shards_from_source`] is the one shared parallel build path
//! — a worker pool capped at [`std::thread::available_parallelism`] (not
//! one unbounded thread per shard, which was pathological at large P) —
//! so the NOMAD engine, DSGD and bulk-sync all consume identical views
//! regardless of whether the bytes came from RAM or from per-shard cache
//! files. [`build_shards`] is the in-memory convenience over the same
//! path.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

use anyhow::Result;

use crate::data::source::{DataSource, InMemorySource};
use crate::data::{Csc, Csr, Dataset, Task};
use crate::kernel::padded_k;

use super::plan::RowPartition;

/// One worker's materialized view of its row shard.
#[derive(Debug, Clone)]
pub struct Shard {
    /// Shard id (= worker id; position in the partition).
    pub id: usize,
    /// Global row range `[start, end)` this shard covers.
    pub start: usize,
    /// Exclusive end of the global row range.
    pub end: usize,
    /// The shard's rows as a local CSR (row `r` = global row `start + r`).
    pub rows: Csr,
    /// Column view of `rows` (local row indices).
    pub cols: Csc,
    /// Labels for the shard's rows.
    pub labels: Vec<f32>,
    /// Task (selects the loss), copied from the dataset.
    pub task: Task,
}

impl Shard {
    /// Materializes shard `id` of `part` through the data seam — the
    /// unit every worker loads for itself (only its own rows; an
    /// out-of-core source reads one shard file, never the full CSR).
    pub fn from_source(src: &dyn DataSource, part: &RowPartition, id: usize) -> Result<Shard> {
        src.shard(part, id)
    }

    /// Number of local rows.
    #[inline]
    pub fn nloc(&self) -> usize {
        self.end - self.start
    }

    /// Fresh lane-blocked per-worker accumulator arenas for a model with
    /// `k` factors: `g`/`acc_xw` are per-row, `aa`/`acc_a`/`acc_s2` are
    /// `nloc x padded_k(k)` with the zero-padding invariant of
    /// [`crate::kernel`].
    pub fn arenas(&self, k: usize) -> ShardArenas {
        let nloc = self.nloc();
        let kp = padded_k(k);
        ShardArenas {
            g: vec![0f32; nloc],
            aa: vec![0f32; nloc * kp],
            acc_xw: vec![0f32; nloc],
            acc_a: vec![0f32; nloc * kp],
            acc_s2: vec![0f32; nloc * kp],
        }
    }
}

/// The per-worker auxiliary-variable arenas (paper's G and A plus the
/// recompute-pass partial sums), lane-blocked.
#[derive(Debug, Clone)]
pub struct ShardArenas {
    /// Loss multipliers G for the local rows.
    pub g: Vec<f32>,
    /// Factor-sum cache A, `nloc x kp` (padding lanes zero).
    pub aa: Vec<f32>,
    /// Linear partial sums (recompute pass).
    pub acc_xw: Vec<f32>,
    /// Factor partial sums, `nloc x kp`.
    pub acc_a: Vec<f32>,
    /// Squared factor partial sums, `nloc x kp`.
    pub acc_s2: Vec<f32>,
}

/// Materializes every shard of `part` over an in-memory dataset. A thin
/// wrapper over [`build_shards_from_source`] with an [`InMemorySource`]
/// view — the shards are bit-for-bit the `slice_rows + to_csc` builds the
/// trainers previously ran inline.
pub fn build_shards(ds: &Dataset, part: &RowPartition) -> Vec<Shard> {
    build_shards_from_source(&InMemorySource::new(ds), part)
        .expect("in-memory shard builds cannot fail")
}

/// Materializes every shard of `part` through the [`DataSource`] seam, in
/// parallel. The worker pool is capped at
/// [`std::thread::available_parallelism`] (and at the shard count):
/// previously P shards spawned P scoped threads, which at large P both
/// oversubscribed the host and — for an out-of-core source — held P shard
/// files in flight at once. Shards come back in shard order; the first
/// shard-load error aborts the build.
pub fn build_shards_from_source(
    src: &dyn DataSource,
    part: &RowPartition,
) -> Result<Vec<Shard>> {
    anyhow::ensure!(
        part.n_rows() == src.n(),
        "partition covers {} rows, source has {}",
        part.n_rows(),
        src.n()
    );
    let p = part.n_shards();
    let threads = std::thread::available_parallelism()
        .map(|t| t.get())
        .unwrap_or(1)
        .clamp(1, p.max(1));
    let next = AtomicUsize::new(0);
    // Raised on the first load error so the pool stops claiming new
    // shards instead of reading (and hash-checking) the rest of a cache
    // that is already known bad.
    let failed = AtomicBool::new(false);
    let mut built: Vec<(usize, Result<Shard>)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                let next = &next;
                let failed = &failed;
                scope.spawn(move || {
                    let mut mine: Vec<(usize, Result<Shard>)> = Vec::new();
                    loop {
                        if failed.load(Ordering::Relaxed) {
                            break;
                        }
                        let id = next.fetch_add(1, Ordering::Relaxed);
                        if id >= p {
                            break;
                        }
                        let res = Shard::from_source(src, part, id);
                        if res.is_err() {
                            failed.store(true, Ordering::Relaxed);
                        }
                        mine.push((id, res));
                    }
                    mine
                })
            })
            .collect();
        let mut all = Vec::with_capacity(p);
        for h in handles {
            all.extend(h.join().expect("shard build panicked"));
        }
        all
    });
    built.sort_by_key(|(id, _)| *id);
    built.into_iter().map(|(_, r)| r).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::partition::RowStrategy;

    #[test]
    fn shards_tile_the_dataset() {
        let ds = synth::table2_dataset("housing", 3).unwrap();
        for strat in [RowStrategy::Contiguous, RowStrategy::NnzBalanced] {
            let part = RowPartition::new(strat, &ds.rows, 4);
            let shards = build_shards(&ds, &part);
            assert_eq!(shards.len(), 4);
            let mut total_rows = 0;
            let mut total_nnz = 0;
            for (b, sh) in shards.iter().enumerate() {
                assert_eq!(sh.id, b);
                assert_eq!((sh.start, sh.end), part.range(b));
                assert_eq!(sh.rows.n_rows(), sh.nloc());
                assert_eq!(sh.rows.n_cols(), ds.d());
                assert_eq!(sh.cols.n_cols(), ds.d());
                assert_eq!(sh.labels.len(), sh.nloc());
                assert_eq!(sh.task, ds.task);
                for r in 0..sh.nloc() {
                    assert_eq!(sh.rows.row(r), ds.rows.row(sh.start + r));
                    assert_eq!(sh.labels[r], ds.labels[sh.start + r]);
                }
                total_rows += sh.nloc();
                total_nnz += sh.rows.nnz();
            }
            assert_eq!(total_rows, ds.n());
            assert_eq!(total_nnz, ds.nnz());
        }
    }

    #[test]
    fn arenas_are_lane_blocked() {
        let ds = synth::table2_dataset("housing", 4).unwrap();
        let part = RowPartition::contiguous(ds.n(), 3);
        let shards = build_shards(&ds, &part);
        let a = shards[0].arenas(5); // kp = 8
        let nloc = shards[0].nloc();
        assert_eq!(a.g.len(), nloc);
        assert_eq!(a.acc_xw.len(), nloc);
        assert_eq!(a.aa.len(), nloc * 8);
        assert_eq!(a.acc_a.len(), nloc * 8);
        assert_eq!(a.acc_s2.len(), nloc * 8);
        assert!(a.aa.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn capped_pool_builds_many_shards_in_order() {
        // 64 shards on a small host: the pool (capped at
        // available_parallelism) must still build every shard, in order —
        // the old path spawned 64 threads for this.
        let ds = synth::table2_dataset("housing", 8).unwrap();
        let part = RowPartition::contiguous(ds.n(), 64);
        let src = crate::data::source::InMemorySource::new(&ds);
        let shards = super::build_shards_from_source(&src, &part).unwrap();
        assert_eq!(shards.len(), 64);
        for (b, sh) in shards.iter().enumerate() {
            assert_eq!(sh.id, b);
            assert_eq!((sh.start, sh.end), part.range(b));
        }
        assert_eq!(shards.iter().map(|s| s.nloc()).sum::<usize>(), ds.n());
        // And the wrapper agrees bit for bit.
        let legacy = build_shards(&ds, &part);
        for (a, b) in shards.iter().zip(&legacy) {
            assert_eq!(a.rows, b.rows);
            assert_eq!(a.labels, b.labels);
        }
    }

    #[test]
    fn empty_shards_are_fine() {
        // More shards than rows: trailing shards are empty but valid.
        let ds = synth::table2_dataset("housing", 5).unwrap();
        let sub = ds.subset(&[0, 1, 2], "tiny");
        let part = RowPartition::contiguous(3, 5);
        let shards = build_shards(&sub, &part);
        assert_eq!(shards.len(), 5);
        assert_eq!(shards[4].nloc(), 0);
        assert_eq!(shards[4].rows.nnz(), 0);
        let a = shards[4].arenas(4);
        assert!(a.g.is_empty() && a.aa.is_empty());
    }
}
