//! Materialized per-worker shard views.
//!
//! A [`Shard`] is what a distributed worker actually touches: the local
//! row-block CSR (a [`Csr::slice_rows`] of the training set), its CSC
//! transpose (the doubly-separable column access path of paper Figs.
//! 1-2), the matching label slice and the task. [`build_shards`] is the
//! one shared construction path — one scoped thread per shard, exactly
//! the parallelism each trainer used to hand-roll inline — so the NOMAD
//! engine, DSGD and bulk-sync all consume identical views.

use crate::data::{Csc, Csr, Dataset, Task};
use crate::kernel::padded_k;

use super::plan::RowPartition;

/// One worker's materialized view of its row shard.
#[derive(Debug, Clone)]
pub struct Shard {
    /// Shard id (= worker id; position in the partition).
    pub id: usize,
    /// Global row range `[start, end)` this shard covers.
    pub start: usize,
    /// Exclusive end of the global row range.
    pub end: usize,
    /// The shard's rows as a local CSR (row `r` = global row `start + r`).
    pub rows: Csr,
    /// Column view of `rows` (local row indices).
    pub cols: Csc,
    /// Labels for the shard's rows.
    pub labels: Vec<f32>,
    /// Task (selects the loss), copied from the dataset.
    pub task: Task,
}

impl Shard {
    /// Number of local rows.
    #[inline]
    pub fn nloc(&self) -> usize {
        self.end - self.start
    }

    /// Fresh lane-blocked per-worker accumulator arenas for a model with
    /// `k` factors: `g`/`acc_xw` are per-row, `aa`/`acc_a`/`acc_s2` are
    /// `nloc x padded_k(k)` with the zero-padding invariant of
    /// [`crate::kernel`].
    pub fn arenas(&self, k: usize) -> ShardArenas {
        let nloc = self.nloc();
        let kp = padded_k(k);
        ShardArenas {
            g: vec![0f32; nloc],
            aa: vec![0f32; nloc * kp],
            acc_xw: vec![0f32; nloc],
            acc_a: vec![0f32; nloc * kp],
            acc_s2: vec![0f32; nloc * kp],
        }
    }
}

/// The per-worker auxiliary-variable arenas (paper's G and A plus the
/// recompute-pass partial sums), lane-blocked.
#[derive(Debug, Clone)]
pub struct ShardArenas {
    /// Loss multipliers G for the local rows.
    pub g: Vec<f32>,
    /// Factor-sum cache A, `nloc x kp` (padding lanes zero).
    pub aa: Vec<f32>,
    /// Linear partial sums (recompute pass).
    pub acc_xw: Vec<f32>,
    /// Factor partial sums, `nloc x kp`.
    pub acc_a: Vec<f32>,
    /// Squared factor partial sums, `nloc x kp`.
    pub acc_s2: Vec<f32>,
}

/// Materializes every shard of `part` over `ds`, in parallel (one scoped
/// thread per shard — the same build parallelism the trainers previously
/// ran inline in their worker threads). Shards come back in shard order.
pub fn build_shards(ds: &Dataset, part: &RowPartition) -> Vec<Shard> {
    assert_eq!(
        part.n_rows(),
        ds.n(),
        "partition covers {} rows, dataset has {}",
        part.n_rows(),
        ds.n()
    );
    std::thread::scope(|scope| {
        let handles: Vec<_> = part
            .bounds()
            .iter()
            .enumerate()
            .map(|(id, &(start, end))| {
                scope.spawn(move || {
                    let rows = ds.rows.slice_rows(start, end);
                    let cols = rows.to_csc();
                    Shard {
                        id,
                        start,
                        end,
                        rows,
                        cols,
                        labels: ds.labels[start..end].to_vec(),
                        task: ds.task,
                    }
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("shard build panicked"))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::partition::RowStrategy;

    #[test]
    fn shards_tile_the_dataset() {
        let ds = synth::table2_dataset("housing", 3).unwrap();
        for strat in [RowStrategy::Contiguous, RowStrategy::NnzBalanced] {
            let part = RowPartition::new(strat, &ds.rows, 4);
            let shards = build_shards(&ds, &part);
            assert_eq!(shards.len(), 4);
            let mut total_rows = 0;
            let mut total_nnz = 0;
            for (b, sh) in shards.iter().enumerate() {
                assert_eq!(sh.id, b);
                assert_eq!((sh.start, sh.end), part.range(b));
                assert_eq!(sh.rows.n_rows(), sh.nloc());
                assert_eq!(sh.rows.n_cols(), ds.d());
                assert_eq!(sh.cols.n_cols(), ds.d());
                assert_eq!(sh.labels.len(), sh.nloc());
                assert_eq!(sh.task, ds.task);
                for r in 0..sh.nloc() {
                    assert_eq!(sh.rows.row(r), ds.rows.row(sh.start + r));
                    assert_eq!(sh.labels[r], ds.labels[sh.start + r]);
                }
                total_rows += sh.nloc();
                total_nnz += sh.rows.nnz();
            }
            assert_eq!(total_rows, ds.n());
            assert_eq!(total_nnz, ds.nnz());
        }
    }

    #[test]
    fn arenas_are_lane_blocked() {
        let ds = synth::table2_dataset("housing", 4).unwrap();
        let part = RowPartition::contiguous(ds.n(), 3);
        let shards = build_shards(&ds, &part);
        let a = shards[0].arenas(5); // kp = 8
        let nloc = shards[0].nloc();
        assert_eq!(a.g.len(), nloc);
        assert_eq!(a.acc_xw.len(), nloc);
        assert_eq!(a.aa.len(), nloc * 8);
        assert_eq!(a.acc_a.len(), nloc * 8);
        assert_eq!(a.acc_s2.len(), nloc * 8);
        assert!(a.aa.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn empty_shards_are_fine() {
        // More shards than rows: trailing shards are empty but valid.
        let ds = synth::table2_dataset("housing", 5).unwrap();
        let sub = ds.subset(&[0, 1, 2], "tiny");
        let part = RowPartition::contiguous(3, 5);
        let shards = build_shards(&sub, &part);
        assert_eq!(shards.len(), 5);
        assert_eq!(shards[4].nloc(), 0);
        assert_eq!(shards[4].rows.nnz(), 0);
        let a = shards[4].arenas(4);
        assert!(a.g.is_empty() && a.aa.is_empty());
    }
}
