//! Partition plans: how rows and parameter columns are split across
//! workers.
//!
//! [`RowPartition`] assigns every example row to exactly one shard as a
//! contiguous range (so a shard is always a [`Csr::slice_rows`] view).
//! Two strategies exist:
//!
//! * [`RowStrategy::Contiguous`] — equal *row counts* (`n.div_ceil(p)`
//!   chunks, clamped to `n`). This is byte-for-byte the chunking every
//!   trainer hand-rolled before this module existed, and stays the
//!   default so existing runs are bitwise unchanged.
//! * [`RowStrategy::NnzBalanced`] — equal *work*: a greedy prefix split
//!   on cumulative row nnz, placing each boundary at the prefix point
//!   nearest the ideal `total_nnz * b / p`. On row-skewed data this
//!   equalizes per-worker nnz (the quantity every column sweep is linear
//!   in); it is guaranteed never to produce a larger max-nnz shard than
//!   the contiguous split (it falls back to the contiguous bounds in the
//!   rare case the greedy cuts would lose).
//!
//! [`ColPartition`] is the column-block side of the grid: one bounds /
//! [`block_range`](ColPartition::block_range) implementation that absorbs
//! the NOMAD engine's token-block math and DSGD's `column_bounds`.
//! [`GridPlan`] composes the two into the (shard x column-block) grid and
//! provides DSGD's block-diagonal stratum schedule.

use anyhow::{bail, ensure, Result};

use crate::data::Csr;

/// How rows are assigned to shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RowStrategy {
    /// Equal row counts (legacy behavior; the default).
    #[default]
    Contiguous,
    /// Greedy prefix split equalizing per-shard nnz.
    NnzBalanced,
}

impl RowStrategy {
    /// Parses the config spelling: `contiguous` or `balanced`
    /// (`nnz-balanced` is accepted as an alias).
    pub fn parse(s: &str) -> Result<RowStrategy> {
        Ok(match s {
            "contiguous" => RowStrategy::Contiguous,
            "balanced" | "nnz-balanced" => RowStrategy::NnzBalanced,
            other => bail!("unknown row partition {other:?} (contiguous|balanced)"),
        })
    }

    /// The config spelling; round-trips through [`RowStrategy::parse`].
    pub fn spec(&self) -> &'static str {
        match self {
            RowStrategy::Contiguous => "contiguous",
            RowStrategy::NnzBalanced => "balanced",
        }
    }
}

/// An assignment of `n` rows to `p` shards as contiguous, ordered,
/// non-overlapping ranges that jointly cover `0..n` (shards may be empty).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RowPartition {
    n: usize,
    strategy: RowStrategy,
    /// Per-shard `[start, end)` ranges, in shard order; `bounds[b].1 ==
    /// bounds[b+1].0` and the last end is `n`.
    bounds: Vec<(usize, usize)>,
}

impl RowPartition {
    /// Builds a partition of `rows` into `p` shards with the given
    /// strategy (the one dispatch point trainers call).
    pub fn new(strategy: RowStrategy, rows: &Csr, p: usize) -> RowPartition {
        match strategy {
            RowStrategy::Contiguous => Self::contiguous(rows.n_rows(), p),
            RowStrategy::NnzBalanced => Self::nnz_balanced(rows, p),
        }
    }

    /// Equal-row-count chunks: shard `b` covers
    /// `[(b*chunk).min(n), ((b+1)*chunk).min(n))` with
    /// `chunk = n.div_ceil(p)` — exactly the legacy chunking of the NOMAD
    /// engine and DSGD, with the clamp bulk-sync's hand-rolled copy was
    /// missing (its `start = p * chunk` could exceed `n`).
    pub fn contiguous(n: usize, p: usize) -> RowPartition {
        let p = p.max(1);
        let chunk = n.div_ceil(p);
        let bounds = (0..p)
            .map(|b| ((b * chunk).min(n), ((b + 1) * chunk).min(n)))
            .collect();
        RowPartition {
            n,
            strategy: RowStrategy::Contiguous,
            bounds,
        }
    }

    /// Rebuilds a partition from stored bounds (the shard-cache manifest
    /// path), validating the structural invariants instead of trusting the
    /// bytes.
    pub fn from_bounds(
        strategy: RowStrategy,
        n: usize,
        bounds: Vec<(usize, usize)>,
    ) -> Result<RowPartition> {
        let part = RowPartition {
            n,
            strategy,
            bounds,
        };
        part.validate()?;
        Ok(part)
    }

    /// Greedy prefix split on cumulative row nnz: boundary `b` lands on
    /// the prefix point nearest the ideal `total_nnz * b / p`. Falls back
    /// to the contiguous bounds whenever the greedy cuts would yield a
    /// *larger* max-nnz shard, so `max shard nnz <= contiguous max shard
    /// nnz` holds unconditionally.
    pub fn nnz_balanced(rows: &Csr, p: usize) -> RowPartition {
        let n = rows.n_rows();
        // prefix[i] = nnz of rows 0..i (non-decreasing).
        let mut prefix = Vec::with_capacity(n + 1);
        prefix.push(0usize);
        for i in 0..n {
            prefix.push(prefix[i] + rows.row_nnz(i));
        }
        Self::nnz_balanced_from_prefix(&prefix, p)
    }

    /// [`RowPartition::nnz_balanced`] computed from a cumulative row-nnz
    /// prefix array (`prefix[i]` = nnz of rows `0..i`, `prefix[0] = 0`) —
    /// the entry point for planners that never materialize a CSR. The
    /// streaming LIBSVM ingester builds this prefix during its single
    /// parse pass and plans through here, so cache-resident partitions are
    /// **bit-identical** to the ones [`RowPartition::new`] computes from
    /// the equivalent in-memory matrix (the boundary math below is shared,
    /// not duplicated).
    pub fn nnz_balanced_from_prefix(prefix: &[usize], p: usize) -> RowPartition {
        assert!(!prefix.is_empty() && prefix[0] == 0, "prefix must start at 0");
        let p = p.max(1);
        let n = prefix.len() - 1;
        let total = prefix[n];
        let contiguous = Self::contiguous(n, p);
        if total == 0 || p == 1 {
            return RowPartition {
                strategy: RowStrategy::NnzBalanced,
                ..contiguous
            };
        }
        let mut cuts = vec![0usize; p + 1];
        cuts[p] = n;
        for b in 1..p {
            let target = total as f64 * b as f64 / p as f64;
            // First prefix point >= target, then pick the nearer of it
            // and its predecessor (ties to the left keeps cuts small).
            let hi = prefix.partition_point(|&x| (x as f64) < target);
            let pick = if hi > n {
                n
            } else if hi == 0 {
                0
            } else {
                let d_hi = prefix[hi] as f64 - target;
                let d_lo = target - prefix[hi - 1] as f64;
                if d_lo <= d_hi {
                    hi - 1
                } else {
                    hi
                }
            };
            cuts[b] = pick.clamp(cuts[b - 1], n);
        }
        let bounds: Vec<(usize, usize)> = (0..p).map(|b| (cuts[b], cuts[b + 1])).collect();
        let max_nnz = |bs: &[(usize, usize)]| {
            bs.iter()
                .map(|&(s, e)| prefix[e] - prefix[s])
                .max()
                .unwrap_or(0)
        };
        if max_nnz(&bounds) <= max_nnz(&contiguous.bounds) {
            RowPartition {
                n,
                strategy: RowStrategy::NnzBalanced,
                bounds,
            }
        } else {
            RowPartition {
                strategy: RowStrategy::NnzBalanced,
                ..contiguous
            }
        }
    }

    /// Number of shards (always the `p` the partition was built with).
    pub fn n_shards(&self) -> usize {
        self.bounds.len()
    }

    /// Number of rows covered.
    pub fn n_rows(&self) -> usize {
        self.n
    }

    /// The strategy this partition was built with.
    pub fn strategy(&self) -> RowStrategy {
        self.strategy
    }

    /// Per-shard `[start, end)` ranges, in shard order.
    pub fn bounds(&self) -> &[(usize, usize)] {
        &self.bounds
    }

    /// Shard `b`'s row range.
    pub fn range(&self, b: usize) -> (usize, usize) {
        self.bounds[b]
    }

    /// Per-shard nnz under this partition.
    pub fn shard_nnz(&self, rows: &Csr) -> Vec<usize> {
        assert_eq!(rows.n_rows(), self.n, "partition built for another matrix");
        self.bounds
            .iter()
            .map(|&(s, e)| (s..e).map(|i| rows.row_nnz(i)).sum())
            .collect()
    }

    /// Structural invariants: ranges are ordered, contiguous and cover
    /// `0..n` exactly (every row in exactly one shard).
    pub fn validate(&self) -> Result<()> {
        ensure!(!self.bounds.is_empty(), "partition has no shards");
        ensure!(self.bounds[0].0 == 0, "first shard does not start at 0");
        ensure!(
            self.bounds.last().unwrap().1 == self.n,
            "last shard ends at {} != n {}",
            self.bounds.last().unwrap().1,
            self.n
        );
        for (b, &(s, e)) in self.bounds.iter().enumerate() {
            ensure!(s <= e, "shard {b}: inverted range {s}..{e}");
            ensure!(e <= self.n, "shard {b}: end {e} > n {}", self.n);
        }
        for w in self.bounds.windows(2) {
            ensure!(
                w[0].1 == w[1].0,
                "gap/overlap between shards: {}..{} then {}..{}",
                w[0].0,
                w[0].1,
                w[1].0,
                w[1].1
            );
        }
        Ok(())
    }
}

/// Block size heuristic for column-block tokens: keep ~64 tokens in
/// flight per worker so the ring stays busy while per-visit dispatch
/// overhead amortizes over many columns. (Moved here from `nomad::token`;
/// the partition layer owns all grid math.)
pub fn auto_block_cols(d: usize, p: usize) -> usize {
    const TOKENS_PER_WORKER: usize = 64;
    (d / (p.max(1) * TOKENS_PER_WORKER)).max(1)
}

/// An even split of `d` parameter columns into fixed-size blocks: block
/// `b` covers `[(b*c).min(d), (b*c + c).min(d))`. One implementation
/// behind both the NOMAD engine's token blocks (sized by columns per
/// token) and DSGD's per-worker column blocks (sized by block count;
/// trailing blocks may be empty when `d` is small).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ColPartition {
    d: usize,
    block: usize,
    nb: usize,
}

impl ColPartition {
    /// Blocks of (at most) `c` columns each — the NOMAD token grid.
    pub fn with_block_size(d: usize, c: usize) -> ColPartition {
        let block = c.max(1);
        ColPartition {
            d,
            block,
            nb: d.div_ceil(block),
        }
    }

    /// Exactly `nb` blocks of `d.div_ceil(nb)` columns each (trailing
    /// blocks empty when `d < nb`) — DSGD's `column_bounds`.
    pub fn with_n_blocks(d: usize, nb: usize) -> ColPartition {
        let nb = nb.max(1);
        ColPartition {
            d,
            block: d.div_ceil(nb).max(1),
            nb,
        }
    }

    /// The auto-granularity grid ([`auto_block_cols`] heuristic).
    pub fn auto(d: usize, p: usize) -> ColPartition {
        Self::with_block_size(d, auto_block_cols(d, p))
    }

    /// Total columns D.
    pub fn d(&self) -> usize {
        self.d
    }

    /// Number of blocks.
    pub fn n_blocks(&self) -> usize {
        self.nb
    }

    /// Columns per (non-ragged) block.
    pub fn block_size(&self) -> usize {
        self.block
    }

    /// Columns `[lo, hi)` of block `b`.
    #[inline]
    pub fn block_range(&self, b: usize) -> (usize, usize) {
        let lo = (b * self.block).min(self.d);
        (lo, (lo + self.block).min(self.d))
    }

    /// The `nb + 1` block boundaries (block `b` covers
    /// `[bounds[b], bounds[b+1])`) — DSGD's legacy `column_bounds` shape.
    pub fn bounds(&self) -> Vec<usize> {
        (0..=self.nb).map(|b| (b * self.block).min(self.d)).collect()
    }
}

/// The (row-shard x column-block) grid and its block-diagonal stratum
/// schedule: in sub-epoch `s`, shard `w` works column block
/// `(w + s) % n_blocks` — no two shards touch the same block, and over
/// `n_subepochs()` sub-epochs every (shard, block) cell is visited
/// exactly once.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GridPlan {
    shards: usize,
    blocks: usize,
}

impl GridPlan {
    /// A grid of `shards` row shards by `blocks` column blocks.
    pub fn new(shards: usize, blocks: usize) -> GridPlan {
        GridPlan {
            shards: shards.max(1),
            blocks: blocks.max(1),
        }
    }

    /// Number of row shards.
    pub fn n_shards(&self) -> usize {
        self.shards
    }

    /// Number of column blocks.
    pub fn n_blocks(&self) -> usize {
        self.blocks
    }

    /// Sub-epochs per epoch (= number of column blocks: after that many,
    /// each shard has visited every block exactly once).
    pub fn n_subepochs(&self) -> usize {
        self.blocks
    }

    /// The column block shard `shard` works in sub-epoch `sub`.
    #[inline]
    pub fn block_for(&self, shard: usize, sub: usize) -> usize {
        (shard + sub) % self.blocks
    }
}

/// Per-shard load summary surfaced in engine / trainer stats.
#[derive(Debug, Clone, Default)]
pub struct PartitionStats {
    /// Stored non-zeros per shard, in shard order.
    pub shard_nnz: Vec<usize>,
    /// Max shard nnz over mean shard nnz: 1.0 is perfectly balanced,
    /// `p` is one shard holding everything. 1.0 when there are no
    /// non-zeros at all (0.0 only in the unmeasured `Default`).
    pub imbalance: f64,
}

impl PartitionStats {
    /// Measures a plan against the matrix it partitions.
    pub fn from_plan(plan: &RowPartition, rows: &Csr) -> PartitionStats {
        PartitionStats::from_shard_nnz(plan.shard_nnz(rows))
    }

    /// Builds the summary from already-known per-shard nnz counts — the
    /// path for streaming sources, where the counts come from a cache
    /// manifest ([`DataSource::shard_nnz_hint`]) and no full CSR exists
    /// to measure.
    ///
    /// [`DataSource::shard_nnz_hint`]: crate::data::DataSource::shard_nnz_hint
    pub fn from_shard_nnz(shard_nnz: Vec<usize>) -> PartitionStats {
        let total: usize = shard_nnz.iter().sum();
        let imbalance = if total == 0 {
            1.0
        } else {
            let mean = total as f64 / shard_nnz.len().max(1) as f64;
            shard_nnz.iter().copied().max().unwrap_or(0) as f64 / mean
        };
        PartitionStats {
            shard_nnz,
            imbalance,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_spec_round_trips() {
        for s in [RowStrategy::Contiguous, RowStrategy::NnzBalanced] {
            assert_eq!(RowStrategy::parse(s.spec()).unwrap(), s);
        }
        assert_eq!(
            RowStrategy::parse("nnz-balanced").unwrap(),
            RowStrategy::NnzBalanced
        );
        assert!(RowStrategy::parse("random").is_err());
    }

    #[test]
    fn contiguous_matches_legacy_chunking() {
        for (n, p) in [(10usize, 3usize), (8, 4), (7, 7), (5, 4), (1, 2), (0, 3), (6, 8)] {
            let part = RowPartition::contiguous(n, p);
            part.validate().unwrap();
            let chunk = n.div_ceil(p.max(1));
            for (b, &(s, e)) in part.bounds().iter().enumerate() {
                assert_eq!(s, (b * chunk).min(n), "n={n} p={p} b={b}");
                assert_eq!(e, ((b + 1) * chunk).min(n), "n={n} p={p} b={b}");
            }
        }
    }

    #[test]
    fn bulksync_clamp_regression_n5_p4() {
        // The exact shape that tripped bulk-sync's hand-rolled chunking:
        // chunk = 2, so the unclamped start of shard 3 was 6 > n = 5.
        let part = RowPartition::contiguous(5, 4);
        part.validate().unwrap();
        assert_eq!(part.bounds(), &[(0, 2), (2, 4), (4, 5), (5, 5)]);
    }

    #[test]
    fn balanced_fixes_front_loaded_skew() {
        // 8 heavy rows (32 nnz) then 56 single-nnz rows: the contiguous
        // quarter split gives shard 0 most of the work.
        let mut triplets = Vec::new();
        for r in 0..8 {
            for c in 0..32 {
                triplets.push((r, c, 1.0f32));
            }
        }
        for r in 8..64 {
            triplets.push((r, r % 32, 1.0f32));
        }
        let m = Csr::from_triplets(64, 32, &triplets);
        let cont = RowPartition::contiguous(64, 4);
        let bal = RowPartition::nnz_balanced(&m, 4);
        bal.validate().unwrap();
        let max = |p: &RowPartition| p.shard_nnz(&m).into_iter().max().unwrap();
        assert_eq!(max(&cont), 8 * 32 + 8);
        assert!(
            max(&bal) < max(&cont) / 2,
            "balanced {} vs contiguous {}",
            max(&bal),
            max(&cont)
        );
        let sc = PartitionStats::from_plan(&cont, &m);
        let sb = PartitionStats::from_plan(&bal, &m);
        assert!(sb.imbalance < sc.imbalance);
        assert!(sb.imbalance >= 1.0 - 1e-12);
    }

    #[test]
    fn balanced_degenerates_gracefully() {
        // No non-zeros / one shard: fall back to the contiguous bounds.
        let empty = Csr::empty(6, 4);
        let part = RowPartition::nnz_balanced(&empty, 3);
        part.validate().unwrap();
        assert_eq!(part.bounds(), RowPartition::contiguous(6, 3).bounds());
        assert_eq!(part.strategy(), RowStrategy::NnzBalanced);
        let m = Csr::from_triplets(3, 2, &[(0, 0, 1.0), (2, 1, 1.0)]);
        let one = RowPartition::nnz_balanced(&m, 1);
        assert_eq!(one.bounds(), &[(0, 3)]);
        assert!((PartitionStats::from_plan(&one, &m).imbalance - 1.0).abs() < 1e-12);
    }

    #[test]
    fn col_partition_absorbs_legacy_bounds() {
        // DSGD's column_bounds shape: exactly p blocks, clamped.
        for (d, p) in [(10usize, 3usize), (8, 4), (7, 7), (5, 8), (1, 2)] {
            let part = ColPartition::with_n_blocks(d, p);
            let b = part.bounds();
            assert_eq!(b.len(), p + 1);
            assert_eq!(b[0], 0);
            assert_eq!(*b.last().unwrap(), d);
            for w in b.windows(2) {
                assert!(w[0] <= w[1]);
            }
            let chunk = d.div_ceil(p);
            for (i, &x) in b.iter().enumerate() {
                assert_eq!(x, (i * chunk).min(d), "d={d} p={p}");
            }
        }
        // The engine's token-block shape: block size c, d.div_ceil(c)
        // blocks, ragged tail.
        let part = ColPartition::with_block_size(13, 5);
        assert_eq!(part.n_blocks(), 3);
        assert_eq!(part.block_range(0), (0, 5));
        assert_eq!(part.block_range(2), (10, 13));
    }

    #[test]
    fn balanced_from_prefix_matches_csr_path() {
        // The ingester plans from a prefix array it builds while parsing;
        // the two entry points must agree exactly (shared boundary math).
        let mut triplets = Vec::new();
        for r in 0..40 {
            for c in 0..(1 + (r * 7) % 13) {
                triplets.push((r, c, 1.0f32));
            }
        }
        let m = Csr::from_triplets(40, 13, &triplets);
        let mut prefix = vec![0usize];
        for i in 0..40 {
            prefix.push(prefix[i] + m.row_nnz(i));
        }
        for p in [1usize, 2, 3, 5, 8, 40, 64] {
            assert_eq!(
                RowPartition::nnz_balanced(&m, p),
                RowPartition::nnz_balanced_from_prefix(&prefix, p),
                "p={p}"
            );
        }
    }

    #[test]
    fn from_bounds_validates_stored_partitions() {
        let good = RowPartition::contiguous(10, 3);
        let back =
            RowPartition::from_bounds(RowStrategy::Contiguous, 10, good.bounds().to_vec())
                .unwrap();
        assert_eq!(back, good);
        // Gap, overlap, wrong n: all rejected.
        assert!(RowPartition::from_bounds(RowStrategy::Contiguous, 10, vec![(0, 4), (5, 10)])
            .is_err());
        assert!(RowPartition::from_bounds(RowStrategy::Contiguous, 10, vec![(0, 6), (5, 10)])
            .is_err());
        assert!(RowPartition::from_bounds(RowStrategy::Contiguous, 9, vec![(0, 5), (5, 10)])
            .is_err());
        assert!(RowPartition::from_bounds(RowStrategy::Contiguous, 10, vec![]).is_err());
    }

    #[test]
    fn auto_heuristic_unchanged() {
        assert_eq!(auto_block_cols(22, 4), 1);
        assert_eq!(auto_block_cols(20_958, 8), 40);
        assert!(auto_block_cols(1, 32) >= 1);
        assert_eq!(ColPartition::auto(20_958, 8).block_size(), 40);
    }
}
