//! DS-FACTO: the paper's hybrid-parallel, decentralized, asynchronous
//! training engine (paper §4, Algorithm 1).
//!
//! * Data is partitioned by **rows** across workers (each worker owns a
//!   contiguous example shard and its column-sliced CSC view, built by
//!   [`crate::partition::build_shards`]; the shard boundaries come from a
//!   [`crate::partition::RowPartition`] — equal row counts by default, or
//!   nnz-balanced via [`NomadConfig::row_partition`]).
//! * The model is partitioned by **columns**: each parameter column
//!   `{w_j, v_j}` circulates as a [`token::Token`] through per-worker
//!   queues in a ring — no parameter server (peer-only topology).
//! * The synchronization terms `G_i` (loss multipliers) and
//!   `a_ik` (factor sums, eq. 10) are maintained as worker-local auxiliary
//!   variables and refreshed by an extra recompute ring pass per outer
//!   iteration (*incremental synchronization*, §4.2), instead of a bulk
//!   synchronization barrier.
//!
//! See [`engine`] for the protocol invariants (including the lane-padded
//! token payload layout). The session-facing entry point is
//! [`crate::train::NomadTrainer`].

// Hot-path module: lint-clean regardless of the workflow-level gate (CI
// additionally runs a clippy pass scoped to kernel + nomad).
#![deny(clippy::all)]

pub mod engine;
pub mod mirror;
pub mod token;

pub use engine::{train_from_source_with_transport, train_with_transport, EngineStats};

use std::time::Duration;

use anyhow::{bail, Context};

use crate::cluster::{LocalTransport, NetModel, SimNetTransport, Transport};
use crate::data::Dataset;
use crate::fm::FmHyper;
use crate::metrics::TrainOutput;
use crate::optim::LrSchedule;
use crate::train::TrainObserver;

/// Which medium tokens move through (the Fig. 6 comparison axis).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TransportKind {
    /// In-process queues (multi-threaded mode).
    Local,
    /// Serialized tokens with a modeled network (multi-machine mode).
    SimNet(NetModel),
    /// Real TCP loopback sockets.
    Tcp,
}

impl TransportKind {
    /// Parses the config spelling: `local`, `tcp`, `simnet` (default
    /// model), or `simnet:LATENCY,BANDWIDTH,WORKERS_PER_MACHINE` — e.g.
    /// `simnet:50us,1e9,2` (latency takes a `us`/`ms`/`s` suffix, bare
    /// numbers are microseconds; bandwidth is bytes/second).
    pub fn parse(s: &str) -> crate::Result<TransportKind> {
        if let Some(rest) = s.strip_prefix("simnet:") {
            let parts: Vec<&str> = rest.split(',').map(str::trim).collect();
            let [lat, bw, wpm] = parts.as_slice() else {
                bail!("simnet spec {s:?}: want simnet:LATENCY,BANDWIDTH,WORKERS_PER_MACHINE");
            };
            let bandwidth_bps: f64 = bw
                .parse()
                .with_context(|| format!("simnet bandwidth {bw:?}"))?;
            anyhow::ensure!(
                bandwidth_bps.is_finite() && bandwidth_bps > 0.0,
                "simnet bandwidth must be a positive finite bytes/sec value, got {bw:?}"
            );
            return Ok(TransportKind::SimNet(NetModel {
                latency: parse_latency(lat)?,
                bandwidth_bps,
                workers_per_machine: wpm
                    .parse::<usize>()
                    .with_context(|| format!("simnet workers-per-machine {wpm:?}"))?,
            }));
        }
        Ok(match s {
            "local" => TransportKind::Local,
            "tcp" => TransportKind::Tcp,
            "simnet" => TransportKind::SimNet(NetModel::default()),
            other => bail!("unknown transport {other:?} (local|simnet[:…]|tcp)"),
        })
    }

    /// The config spelling; round-trips through [`TransportKind::parse`]
    /// exactly (the latency is emitted in the coarsest unit that loses
    /// nothing, down to nanoseconds).
    pub fn spec(&self) -> String {
        match self {
            TransportKind::Local => "local".to_string(),
            TransportKind::Tcp => "tcp".to_string(),
            TransportKind::SimNet(m) => {
                let ns = m.latency.as_nanos();
                let lat = if ns % 1_000_000_000 == 0 {
                    format!("{}s", ns / 1_000_000_000)
                } else if ns % 1_000_000 == 0 {
                    format!("{}ms", ns / 1_000_000)
                } else if ns % 1_000 == 0 {
                    format!("{}us", ns / 1_000)
                } else {
                    format!("{ns}ns")
                };
                format!("simnet:{lat},{},{}", m.bandwidth_bps, m.workers_per_machine)
            }
        }
    }
}

/// Parses a latency like `50us`, `2ms`, `0.1s`, `500ns`; bare numbers are
/// microseconds.
fn parse_latency(s: &str) -> crate::Result<Duration> {
    let (num, scale_ns) = if let Some(x) = s.strip_suffix("us") {
        (x, 1e3)
    } else if let Some(x) = s.strip_suffix("ms") {
        (x, 1e6)
    } else if let Some(x) = s.strip_suffix("ns") {
        (x, 1.0)
    } else if let Some(x) = s.strip_suffix('s') {
        (x, 1e9)
    } else {
        (s, 1e3)
    };
    let v: f64 = num
        .parse()
        .with_context(|| format!("latency {s:?}"))?;
    anyhow::ensure!(v >= 0.0 && v.is_finite(), "latency {s:?} out of range");
    Ok(Duration::from_nanos((v * scale_ns).round() as u64))
}

/// How an update-phase token visit applies eqs. 12-13 (both use the frozen
/// auxiliary G/A; they differ in how example contributions combine).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UpdateMode {
    /// Fold the whole local column into one 1/N-normalized gradient step
    /// per visit: an outer iteration equals one incremental full-gradient
    /// pass. Stable at batch-GD step sizes; the default.
    MeanGradient,
    /// Paper-literal Algorithm 1 line 14: sample `samples` local examples
    /// and apply the *stochastic* eq. 12/13 update per example. Noisier,
    /// escapes saddles (e.g. FM-as-MF factor growth) that full-gradient
    /// steps crawl out of; use per-example-SGD-scale step sizes.
    Stochastic {
        /// Stochastic updates applied per token visit.
        samples: usize,
    },
}

impl UpdateMode {
    /// Parses the config spelling: `mean` (or `mean-gradient`), or
    /// `stochastic[:SAMPLES]` (default 1 sample per visit).
    pub fn parse(s: &str) -> crate::Result<UpdateMode> {
        if let Some(n) = s.strip_prefix("stochastic:") {
            return Ok(UpdateMode::Stochastic {
                samples: n
                    .trim()
                    .parse::<usize>()
                    .with_context(|| format!("stochastic samples {n:?}"))?
                    .max(1),
            });
        }
        Ok(match s {
            "mean" | "mean-gradient" => UpdateMode::MeanGradient,
            "stochastic" => UpdateMode::Stochastic { samples: 1 },
            other => bail!("unknown update mode {other:?} (mean|stochastic[:N])"),
        })
    }

    /// The config spelling; round-trips through [`UpdateMode::parse`].
    pub fn spec(&self) -> String {
        match self {
            UpdateMode::MeanGradient => "mean".to_string(),
            UpdateMode::Stochastic { samples } => format!("stochastic:{samples}"),
        }
    }
}

/// DS-FACTO engine configuration.
#[derive(Debug, Clone)]
pub struct NomadConfig {
    /// Worker count P.
    pub workers: usize,
    /// Outer iterations T (each = one update pass + one recompute pass).
    pub outer_iters: usize,
    /// Learning-rate schedule.
    pub eta: LrSchedule,
    /// Seed for init and token dealing.
    pub seed: u64,
    /// Evaluate held-out metrics every this many outer iterations.
    pub eval_every: usize,
    /// Token medium.
    pub transport: TransportKind,
    /// Update-visit semantics.
    pub update_mode: UpdateMode,
    /// Columns carried per token (block granularity). 0 = auto heuristic
    /// (`partition::auto_block_cols`): wide models circulate column blocks
    /// so per-visit dispatch overhead amortizes — the §Perf optimization
    /// that makes realsim-scale models scale (EXPERIMENTS.md §Perf).
    pub cols_per_token: usize,
    /// How rows are sharded across workers: `Contiguous` (equal row
    /// counts; the default, bitwise identical to the legacy chunking) or
    /// `NnzBalanced` (equal per-shard nnz on row-skewed data).
    pub row_partition: crate::partition::RowStrategy,
    /// Where workers pull their row shards from: in-memory slices of the
    /// training set (the default — bit-identical to the legacy build), or
    /// per-worker shard-cache files (`data_cache = <dir>`), so each
    /// worker thread loads only its own shard and never the full CSR.
    pub source: crate::data::ShardSource,
}

impl Default for NomadConfig {
    fn default() -> Self {
        NomadConfig {
            workers: 4,
            outer_iters: 50,
            // One outer iteration applies ~one 1/N-normalized gradient pass
            // (see engine::Worker::update_visit), so the stable step size is
            // batch-GD-scale, much larger than per-example SGD's.
            eta: LrSchedule::Constant(0.5),
            seed: 42,
            eval_every: 1,
            transport: TransportKind::Local,
            update_mode: UpdateMode::MeanGradient,
            cols_per_token: 0,
            row_partition: crate::partition::RowStrategy::Contiguous,
            source: crate::data::ShardSource::InMemory,
        }
    }
}

/// Trains an FM with DS-FACTO; the transport is built from the config.
pub fn train(
    train_ds: &Dataset,
    test: Option<&Dataset>,
    fm: &FmHyper,
    cfg: &NomadConfig,
) -> crate::Result<TrainOutput> {
    train_with_stats(train_ds, test, fm, cfg).map(|(out, _)| out)
}

/// Like [`train`] but also returns engine counters.
pub fn train_with_stats(
    train_ds: &Dataset,
    test: Option<&Dataset>,
    fm: &FmHyper,
    cfg: &NomadConfig,
) -> crate::Result<(TrainOutput, EngineStats)> {
    train_with_observer(train_ds, test, fm, cfg, &mut ())
}

/// Like [`train_with_stats`], reporting every outer iteration to `obs`
/// (see the observer contract in [`crate::train`]). This is what
/// [`crate::train::NomadTrainer`] calls.
pub fn train_with_observer(
    train_ds: &Dataset,
    test: Option<&Dataset>,
    fm: &FmHyper,
    cfg: &NomadConfig,
    obs: &mut dyn TrainObserver,
) -> crate::Result<(TrainOutput, EngineStats)> {
    // Serializing transports are told the factor width K so they can
    // strip the engine's lane-padded payloads to the K-strided wire form
    // (and re-pad on receive): the byte format on the wire is unchanged
    // by the in-memory layout.
    match cfg.transport {
        TransportKind::Local => {
            let t = LocalTransport::new(cfg.workers.max(1));
            engine::run(train_ds, test, fm, cfg, &t, obs)
        }
        TransportKind::SimNet(model) => {
            let t = SimNetTransport::new(cfg.workers.max(1), model, Some(fm.k));
            let out = engine::run(train_ds, test, fm, cfg, &*t, obs);
            t.shutdown();
            out
        }
        TransportKind::Tcp => {
            let t = crate::cluster::tcp::TcpTransport::new(cfg.workers.max(1), Some(fm.k))?;
            let out = engine::run(train_ds, test, fm, cfg, &*t, obs);
            t.shutdown();
            out
        }
    }
}

/// Like [`train_with_observer`], but fed by a [`DataSource`] instead of an
/// in-memory pair: workers pull their shards straight from the source
/// (`cfg.source` is ignored) and nothing materializes the full matrix. The
/// iter-0 trace point streams shard by shard; there is no held-out set —
/// evaluate afterwards with [`crate::train::streaming_eval`].
///
/// [`DataSource`]: crate::data::DataSource
pub fn train_from_source(
    src: &dyn crate::data::DataSource,
    fm: &FmHyper,
    cfg: &NomadConfig,
    obs: &mut dyn TrainObserver,
) -> crate::Result<(TrainOutput, EngineStats)> {
    match cfg.transport {
        TransportKind::Local => {
            let t = LocalTransport::new(cfg.workers.max(1));
            engine::run_from_source(src, fm, cfg, &t, obs)
        }
        TransportKind::SimNet(model) => {
            let t = SimNetTransport::new(cfg.workers.max(1), model, Some(fm.k));
            let out = engine::run_from_source(src, fm, cfg, &*t, obs);
            t.shutdown();
            out
        }
        TransportKind::Tcp => {
            let t = crate::cluster::tcp::TcpTransport::new(cfg.workers.max(1), Some(fm.k))?;
            let out = engine::run_from_source(src, fm, cfg, &*t, obs);
            t.shutdown();
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::{libfm_train, LibfmConfig};
    use crate::data::synth;
    use crate::metrics::evaluate;

    fn housing() -> Dataset {
        synth::table2_dataset("housing", 1).unwrap()
    }

    #[test]
    fn single_worker_converges() {
        let ds = housing();
        let fm = FmHyper {
            k: 4,
            ..Default::default()
        };
        let cfg = NomadConfig {
            workers: 1,
            outer_iters: 40,
            eta: LrSchedule::Constant(0.5),
            ..Default::default()
        };
        let out = train(&ds, None, &fm, &cfg).unwrap();
        let first = out.trace.first().unwrap().objective;
        let last = out.trace.last().unwrap().objective;
        assert!(last < 0.5 * first, "{first} -> {last}");
        assert_eq!(out.trace.len(), 41);
    }

    #[test]
    fn four_workers_converge_to_libfm_quality() {
        let ds = synth::table2_dataset("diabetes", 2).unwrap();
        let (train_ds, test_ds) = ds.split(0.8, 3);
        let fm = FmHyper {
            k: 4,
            ..Default::default()
        };
        let cfg = NomadConfig {
            workers: 4,
            outer_iters: 50,
            eta: LrSchedule::Constant(0.5),
            ..Default::default()
        };
        let out = train(&train_ds, Some(&test_ds), &fm, &cfg).unwrap();
        let nomad_acc = evaluate(&out.model, &test_ds).accuracy;

        let lcfg = LibfmConfig {
            epochs: 30,
            eta: LrSchedule::Constant(0.02),
            ..Default::default()
        };
        let lout = libfm_train(&train_ds, Some(&test_ds), &fm, &lcfg, &mut ());
        let libfm_acc = evaluate(&lout.model, &test_ds).accuracy;
        // Paper Fig. 5: DS-FACTO reaches the same quality as libFM.
        assert!(
            nomad_acc > libfm_acc - 0.05,
            "nomad {nomad_acc} vs libfm {libfm_acc}"
        );
    }

    #[test]
    fn trace_is_complete_and_ordered() {
        let ds = housing();
        let fm = FmHyper::default();
        let cfg = NomadConfig {
            workers: 3,
            outer_iters: 7,
            ..Default::default()
        };
        let out = train(&ds, None, &fm, &cfg).unwrap();
        assert_eq!(out.trace.len(), 8);
        for (i, pt) in out.trace.iter().enumerate() {
            assert_eq!(pt.iter, i);
        }
        assert!(out.trace.windows(2).all(|w| w[0].secs <= w[1].secs));
    }

    #[test]
    fn stats_account_for_all_hops() {
        let ds = housing();
        let d = ds.d();
        let fm = FmHyper::default();
        let p = 3;
        let t = 5;
        let cfg = NomadConfig {
            workers: p,
            outer_iters: t,
            ..Default::default()
        };
        let (_, stats) = train_with_stats(&ds, None, &fm, &cfg).unwrap();
        // Hops: initial deal (ntok) + one send per visit per phase:
        // ntok * P * 2 phases * T iters.
        let ntok = (d + 1) as u64;
        let expected = ntok + ntok * (p as u64) * 2 * (t as u64);
        assert_eq!(stats.messages, expected);
        // Update visits: every non-bias token visits every worker once per
        // update pass (bias visits counted too).
        assert_eq!(stats.update_visits, ntok * p as u64 * t as u64);
    }

    #[test]
    fn simnet_transport_reaches_same_quality() {
        let ds = housing();
        let fm = FmHyper {
            k: 4,
            ..Default::default()
        };
        let model = NetModel {
            latency: std::time::Duration::from_micros(50),
            bandwidth_bps: 1e9,
            workers_per_machine: 2,
        };
        let cfg = NomadConfig {
            workers: 4,
            outer_iters: 15,
            eta: LrSchedule::Constant(0.5),
            transport: TransportKind::SimNet(model),
            ..Default::default()
        };
        let (out, stats) = train_with_stats(&ds, None, &fm, &cfg).unwrap();
        assert!(out.trace.last().unwrap().objective < 0.6 * out.trace[0].objective);
        assert!(stats.bytes > 0, "cross-machine hops must serialize");
    }

    #[test]
    fn worker_count_exceeding_rows_is_safe() {
        let spec = synth::SynthSpec {
            n: 6,
            ..synth::SynthSpec::table2("housing").unwrap()
        };
        let ds = synth::generate(&spec, 4).dataset;
        let fm = FmHyper::default();
        let cfg = NomadConfig {
            workers: 8, // more workers than rows: some blocks are empty
            outer_iters: 3,
            ..Default::default()
        };
        let out = train(&ds, None, &fm, &cfg).unwrap();
        assert_eq!(out.trace.len(), 4);
    }

    #[test]
    fn stochastic_mode_converges() {
        let ds = housing();
        let fm = FmHyper {
            k: 4,
            ..Default::default()
        };
        let cfg = NomadConfig {
            workers: 4,
            outer_iters: 40,
            eta: LrSchedule::Constant(0.02),
            update_mode: UpdateMode::Stochastic { samples: 2 },
            ..Default::default()
        };
        let out = train(&ds, None, &fm, &cfg).unwrap();
        let first = out.trace.first().unwrap().objective;
        let last = out.trace.last().unwrap().objective;
        assert!(last < 0.7 * first, "stochastic mode: {first} -> {last}");
    }

    #[test]
    fn block_tokens_match_single_column_quality() {
        // Granularity must not change what is computed, only how it is
        // batched: same mean-gradient pass either way.
        let ds = housing();
        let fm = FmHyper {
            k: 4,
            ..Default::default()
        };
        let run = |cols| {
            let cfg = NomadConfig {
                workers: 1, // deterministic schedule
                outer_iters: 10,
                eta: LrSchedule::Constant(0.5),
                cols_per_token: cols,
                ..Default::default()
            };
            train(&ds, None, &fm, &cfg).unwrap()
        };
        let single = run(1);
        let blocked = run(5);
        let (a, b) = (
            single.trace.last().unwrap().objective,
            blocked.trace.last().unwrap().objective,
        );
        assert!(
            (a - b).abs() < 1e-6 * (1.0 + a.abs()),
            "single-col {a} vs blocked {b}"
        );
    }

    #[test]
    fn block_token_count_accounting() {
        let ds = housing(); // d = 13
        let fm = FmHyper::default();
        let cfg = NomadConfig {
            workers: 2,
            outer_iters: 3,
            cols_per_token: 5, // 3 blocks + bias = 4 tokens
            ..Default::default()
        };
        let (_, stats) = train_with_stats(&ds, None, &fm, &cfg).unwrap();
        let ntok = 4u64;
        assert_eq!(stats.messages, ntok + ntok * 2 * 2 * 3);
    }

    #[test]
    fn deterministic_final_model_single_worker() {
        // With P=1 there is no cross-worker nondeterminism at all.
        let ds = housing();
        let fm = FmHyper::default();
        let cfg = NomadConfig {
            workers: 1,
            outer_iters: 4,
            ..Default::default()
        };
        let a = train(&ds, None, &fm, &cfg).unwrap();
        let b = train(&ds, None, &fm, &cfg).unwrap();
        assert_eq!(a.model, b.model);
    }

    #[test]
    fn deterministic_final_model_multi_worker() {
        // Mean-gradient updates fold deferred recompute payloads in a
        // canonical (sorted) order before finalizing, so the final model
        // is bitwise reproducible even with P>1 racing workers — the
        // invariant the multi-process cluster's bitwise-equality e2e
        // tests build on.
        let ds = housing();
        let fm = FmHyper::default();
        let cfg = NomadConfig {
            workers: 3,
            outer_iters: 4,
            cols_per_token: 5,
            ..Default::default()
        };
        let a = train(&ds, None, &fm, &cfg).unwrap();
        let b = train(&ds, None, &fm, &cfg).unwrap();
        assert_eq!(a.model, b.model);
    }

    #[test]
    fn transport_spec_round_trips() {
        for spec in [
            "local",
            "tcp",
            "simnet:50us,1000000000,2",
            "simnet:0.5us,1e9,1", // sub-microsecond: re-emitted as 500ns
            "simnet:2s,1e6,4",
        ] {
            let t = TransportKind::parse(spec).unwrap();
            assert_eq!(TransportKind::parse(&t.spec()).unwrap(), t, "{spec}");
        }
        match TransportKind::parse("simnet:0.5us,1e9,1").unwrap() {
            TransportKind::SimNet(m) => {
                assert_eq!(m.latency, Duration::from_nanos(500));
            }
            other => panic!("{other:?}"),
        }
        let t = TransportKind::parse("simnet:2ms,1.25e9,4").unwrap();
        match t {
            TransportKind::SimNet(m) => {
                assert_eq!(m.latency, Duration::from_millis(2));
                assert_eq!(m.bandwidth_bps, 1.25e9);
                assert_eq!(m.workers_per_machine, 4);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(
            TransportKind::parse("simnet").unwrap(),
            TransportKind::SimNet(NetModel::default())
        );
        assert!(TransportKind::parse("carrier-pigeon").is_err());
        assert!(TransportKind::parse("simnet:1us").is_err());
        // Bandwidth must be positive and finite — a zero/NaN value would
        // panic inside the transport's Duration arithmetic mid-run.
        assert!(TransportKind::parse("simnet:1us,0,1").is_err());
        assert!(TransportKind::parse("simnet:1us,-1e9,1").is_err());
        assert!(TransportKind::parse("simnet:1us,NaN,1").is_err());
    }

    #[test]
    fn update_mode_spec_round_trips() {
        for spec in ["mean", "stochastic:4"] {
            let m = UpdateMode::parse(spec).unwrap();
            assert_eq!(UpdateMode::parse(&m.spec()).unwrap(), m, "{spec}");
        }
        assert_eq!(
            UpdateMode::parse("stochastic").unwrap(),
            UpdateMode::Stochastic { samples: 1 }
        );
        assert_eq!(UpdateMode::parse("mean-gradient").unwrap(), UpdateMode::MeanGradient);
        assert!(UpdateMode::parse("adam").is_err());
    }

    #[test]
    fn observer_stop_is_honored_within_pipeline_depth() {
        struct StopAt(usize);
        impl TrainObserver for StopAt {
            fn on_iter(
                &mut self,
                pt: &crate::metrics::TracePoint,
                _m: Option<&crate::fm::FmModel>,
            ) -> crate::train::ControlFlow {
                if pt.iter >= self.0 {
                    crate::train::ControlFlow::Stop
                } else {
                    crate::train::ControlFlow::Continue
                }
            }
        }
        let ds = housing();
        let fm = FmHyper::default();
        let cfg = NomadConfig {
            workers: 3,
            outer_iters: 40,
            ..Default::default()
        };
        let (out, _) = train_with_observer(&ds, None, &fm, &cfg, &mut StopAt(5)).unwrap();
        let last = out.trace.last().unwrap().iter;
        assert!(last >= 5, "stopped too early: {last}");
        assert!(last <= 8, "stop not honored within pipeline depth: {last}");
        // The trace stays complete and ordered up to the stop.
        for (i, pt) in out.trace.iter().enumerate() {
            assert_eq!(pt.iter, i);
        }
    }

    #[test]
    fn observer_stop_at_iter_zero_skips_training() {
        struct StopNow;
        impl TrainObserver for StopNow {
            fn on_iter(
                &mut self,
                _pt: &crate::metrics::TracePoint,
                _m: Option<&crate::fm::FmModel>,
            ) -> crate::train::ControlFlow {
                crate::train::ControlFlow::Stop
            }
        }
        let ds = housing();
        let fm = FmHyper::default();
        let cfg = NomadConfig {
            workers: 2,
            outer_iters: 10,
            ..Default::default()
        };
        let (out, stats) = train_with_observer(&ds, None, &fm, &cfg, &mut StopNow).unwrap();
        assert_eq!(out.trace.len(), 1);
        assert_eq!(stats.messages, 0);
    }
}
