//! A lock-free read-mostly mirror of the circulating parameters.
//!
//! The true parameters live inside tokens (single-owner, no locks). For
//! held-out evaluation during training the driver needs *approximate*
//! snapshots without pausing the ring, so the last visitor of each token's
//! Recompute pass publishes the column here (one relaxed atomic store per
//! value, once per token per iteration).
//!
//! Snapshots are **eventually consistent**: a reader may observe columns
//! from adjacent iterations. That is inherent to asynchronous execution —
//! the paper evaluates the same way (convergence curves from periodic
//! snapshots) — and the *final* model is assembled exactly from the tokens
//! themselves, not from the mirror.

use std::sync::atomic::{AtomicU32, Ordering};

use crate::fm::FmModel;

/// Atomic f32 array mirror of `w0`, `w`, `V`.
pub struct ParamMirror {
    d: usize,
    k: usize,
    w0: AtomicU32,
    w: Vec<AtomicU32>,
    v: Vec<AtomicU32>,
}

#[inline]
fn store(cell: &AtomicU32, x: f32) {
    cell.store(x.to_bits(), Ordering::Relaxed);
}

#[inline]
fn load(cell: &AtomicU32) -> f32 {
    f32::from_bits(cell.load(Ordering::Relaxed))
}

impl ParamMirror {
    /// Initializes the mirror from the starting model.
    pub fn new(init: &FmModel) -> Self {
        ParamMirror {
            d: init.d,
            k: init.k,
            w0: AtomicU32::new(init.w0.to_bits()),
            w: init.w.iter().map(|&x| AtomicU32::new(x.to_bits())).collect(),
            v: init.v.iter().map(|&x| AtomicU32::new(x.to_bits())).collect(),
        }
    }

    /// Publishes column `j`. `v` is the K-strided factor row: the engine
    /// strips its lane-padded token payloads to the K real lanes at this
    /// edge (the mirror, like `FmModel`, never stores padding).
    pub fn publish_column(&self, j: usize, w: f32, v: &[f32]) {
        debug_assert_eq!(v.len(), self.k);
        store(&self.w[j], w);
        for (kk, &x) in v.iter().enumerate() {
            store(&self.v[j * self.k + kk], x);
        }
    }

    /// Publishes the bias.
    pub fn publish_bias(&self, w0: f32) {
        store(&self.w0, w0);
    }

    /// Copies the mirror into a plain model.
    pub fn snapshot(&self) -> FmModel {
        FmModel {
            d: self.d,
            k: self.k,
            w0: load(&self.w0),
            w: self.w.iter().map(load).collect(),
            v: self.v.iter().map(load).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_reflects_publishes() {
        let init = FmModel::zeros(3, 2);
        let m = ParamMirror::new(&init);
        m.publish_column(1, 0.5, &[1.0, 2.0]);
        m.publish_bias(-0.25);
        let snap = m.snapshot();
        assert_eq!(snap.w0, -0.25);
        assert_eq!(snap.w, vec![0.0, 0.5, 0.0]);
        assert_eq!(snap.vrow(1), &[1.0, 2.0]);
        assert_eq!(snap.vrow(0), &[0.0, 0.0]);
    }

    #[test]
    fn initial_snapshot_equals_init() {
        let mut init = FmModel::zeros(2, 2);
        init.w0 = 3.0;
        init.w[1] = 4.0;
        init.v[3] = 5.0;
        let m = ParamMirror::new(&init);
        assert_eq!(m.snapshot(), init);
    }

    #[test]
    fn concurrent_publish_and_snapshot_are_safe() {
        let init = FmModel::zeros(64, 4);
        let m = std::sync::Arc::new(ParamMirror::new(&init));
        let writer = {
            let m = std::sync::Arc::clone(&m);
            std::thread::spawn(move || {
                for round in 0..200 {
                    for j in 0..64 {
                        let x = (round * 64 + j) as f32;
                        m.publish_column(j, x, &[x; 4]);
                    }
                }
            })
        };
        for _ in 0..50 {
            let snap = m.snapshot();
            assert_eq!(snap.w.len(), 64);
        }
        writer.join().unwrap();
        let snap = m.snapshot();
        assert_eq!(snap.w[63], (199 * 64 + 63) as f32);
    }
}
