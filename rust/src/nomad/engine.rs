//! The DS-FACTO execution engine: P workers, a ring of circulating
//! parameter tokens, and the two-pass (update / recompute) protocol of
//! paper Algorithm 1 with incremental synchronization of G and A.
//!
//! The (row-shard x column-block) grid comes from [`crate::partition`]:
//! rows through a [`crate::partition::RowPartition`] (contiguous by
//! default, nnz-balanced via `NomadConfig::row_partition`) materialized
//! through the [`crate::data::DataSource`] seam by
//! [`partition::build_shards_from_source`] (in-memory slices by default;
//! per-worker shard-cache files under `NomadConfig::source`), columns
//! through the [`ColPartition`] tokens are cut from.
//!
//! ## Protocol invariants (tested in `nomad::tests` and `rust/tests/`)
//!
//! 1. **Single ownership** — a token is held by exactly one worker at a
//!    time; parameters need no locks.
//! 2. **Phase lockstep (+/-1)** — a worker at phase sequence `s` only ever
//!    receives tokens at `s` (processed) or `s+1` (held back); tokens never
//!    arrive *behind* a worker.
//! 3. **Conservation** — every token makes exactly `P` visits per phase and
//!    is collected exactly once at the end; no token is lost or duplicated.
//! 4. **Exact finalization** — the returned model is assembled from the
//!    tokens themselves (not the eventually-consistent mirror).
//!
//! ## Memory layout (lane-blocked hot path)
//!
//! Every per-visit inner loop runs through the column-visit kernels in
//! [`crate::kernel::visit`] over `kp = padded_k(k)`-strided buffers:
//! token factor payloads are dealt lane-padded from the init
//! [`FmKernel`], and the worker arenas `aa` / `acc_a` / `acc_s2` are
//! `nloc x kp` with invariantly-zero padding lanes. Padding is stripped
//! only at the edges — the wire codec (the TCP/simnet byte format is the
//! K-strided one, unchanged), the mirror publish, and the final model
//! assembly. The kernels apply identical per-coordinate operation order
//! to the scalar loops they replaced, so results are bitwise unchanged
//! (`rust/tests/engine_properties.rs` asserts this end to end).
//!
//! That bitwise guarantee survives SIMD dispatch: the visit kernels (and
//! the fused scoring path behind `seed_arenas`) select their backend via
//! [`crate::kernel::backend`], and every AVX2 variant the engine can
//! reach is non-FMA with scalar-ordered reductions — bitwise-identical
//! to the lane loops — so an engine run produces the same bits whether
//! the process picked `lanes` (e.g. under `DSFACTO_NO_SIMD=1`) or
//! `avx2`. The backend is chosen once per process, so all worker threads
//! agree.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::sync::Mutex;
use std::time::Duration;

use anyhow::{ensure, Context, Result};

use super::mirror::ParamMirror;
use super::token::{Phase, Token, BIAS};
use super::NomadConfig;
use crate::cluster::Transport;
use crate::data::{Csc, Dataset, Task};
use crate::fm::{loss, FmHyper, FmModel};
use crate::kernel::{padded_k, visit, FmKernel, Scratch};
use crate::metrics::{evaluate, TracePoint, TrainOutput};
use crate::optim::LrSchedule;
use crate::partition::{self, ColPartition, PartitionStats};
use crate::train::TrainObserver;
use crate::util::rng::Pcg64;
use crate::util::timer::Stopwatch;

/// Engine-level counters (Fig. 6 analysis; transport adds its own).
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    /// Token hops through the transport.
    pub messages: u64,
    /// Serialized bytes (simulated / TCP transports only).
    pub bytes: u64,
    /// Update-phase token visits processed.
    pub update_visits: u64,
    /// Coordinate updates applied (sum over visits of local column nnz).
    pub coordinate_updates: u64,
    /// Peak holdback-queue length observed on any worker.
    pub holdback_peak: usize,
    /// Per-worker busy seconds: time spent processing tokens (update,
    /// recompute, finalize, serialization), excluding queue waits.
    ///
    /// On machines with fewer cores than workers, wall-clock speedup is
    /// meaningless; `busy` gives the *simulated parallel makespan*
    /// `max_p busy_p` — the quantity the Fig. 6 reproduction reports
    /// (EXPERIMENTS.md documents this substitution).
    pub worker_busy_secs: Vec<f64>,
    /// The row-shard load summary of this run (per-shard nnz and the
    /// max/mean imbalance ratio) — EXPERIMENTS.md §Partitioning.
    pub partition: PartitionStats,
}

impl EngineStats {
    /// Simulated parallel makespan: the slowest worker's busy time.
    pub fn makespan_secs(&self) -> f64 {
        self.worker_busy_secs.iter().cloned().fold(0.0, f64::max)
    }

    /// Total busy time across workers (the "work" in work-span terms).
    pub fn total_busy_secs(&self) -> f64 {
        self.worker_busy_secs.iter().sum()
    }
}

/// A worker's end-of-recompute report (drives the convergence trace).
/// `pub(crate)` because the multi-process runtime
/// ([`crate::cluster::runtime`]) forwards these to the driver as control
/// frames instead of aggregating them in-process.
pub(crate) struct FinalizePost {
    pub(crate) iter: u32,
    pub(crate) loss_sum: f64,
    pub(crate) n_local: usize,
    /// Sum of w_j^2 over tokens this worker flipped this iteration.
    pub(crate) reg_w: f64,
    /// Sum of ||v_j||^2 over tokens this worker flipped this iteration.
    pub(crate) reg_v: f64,
}

/// A checkpoint-stream message: the engine emits the post-flip clone of
/// every token a worker flips at a checkpointed epoch boundary, then one
/// `EpochDone` marker once that worker's recompute pass finalizes. The
/// receiving thread persists each completed set via
/// [`crate::train::Checkpointer::save_blocks`].
pub(crate) enum CkptMsg {
    /// A token flipped into the update phase of the tagged iteration —
    /// exactly the state it must be re-dealt with on restart.
    Block(Token),
    /// All blocks this worker flips for the tagged iteration were sent.
    EpochDone(u32),
}

/// Per-epoch checkpoint hook carried by a worker (multi-process runtime
/// only; the in-process engine leaves it `None`).
pub(crate) struct CkptHook {
    /// Checkpoint every this many completed outer iterations.
    pub(crate) every: u32,
    /// Where the block stream goes.
    pub(crate) tx: Sender<CkptMsg>,
}

/// Shared engine context (borrowed by every worker). `pub(crate)` so the
/// multi-process runtime can host a single [`Worker`] over a remote
/// transport with driver-fed `stop_at` / `driver_iters` values.
pub(crate) struct Shared<'a> {
    pub(crate) transport: &'a dyn Transport,
    /// Eventually-consistent parameter mirror for snapshots/eval. `None`
    /// in a multi-process worker, which never snapshots (the driver
    /// assembles the final model from collected tokens).
    pub(crate) mirror: Option<&'a ParamMirror>,
    pub(crate) collector: Mutex<Vec<Token>>,
    pub(crate) collected: AtomicUsize,
    pub(crate) done: AtomicBool,
    pub(crate) update_visits: AtomicU64,
    pub(crate) coordinate_updates: AtomicU64,
    pub(crate) holdback_peak: AtomicUsize,
    pub(crate) busy_secs: Mutex<Vec<f64>>,
    /// The iteration at which tokens are collected instead of processed;
    /// `u32::MAX` until the observer requests an early stop. The driver
    /// sets `aggregated_iter + 4` after completing iteration
    /// `aggregated_iter` (pipeline bound of 2 beyond the already-published
    /// count, plus one phase of token lead): combined with the
    /// `driver_iters` gate below, no worker can process that iteration's
    /// update phase, so every token is still collected at one single
    /// iteration with exact finalization (invariant 4).
    pub(crate) stop_at: AtomicU32,
    /// Iterations the driver has fully aggregated — published *before* the
    /// driver's own snapshot/eval/observer work, so that work never sits
    /// on the workers' critical path. Workers never enter the update phase
    /// of iteration `j` until `j <= driver_iters + 2` — a
    /// bounded-pipelining rule that (a) costs nothing in normal operation
    /// (aggregation is trivially fast) and (b) bounds how far training can
    /// overrun an observer's stop request.
    pub(crate) driver_iters: AtomicU32,
}

/// Per-worker engine state. `pub(crate)` (with `pub(crate)` fields)
/// because the multi-process runtime constructs one `Worker` per OS
/// process over a remote transport; the in-process engine builds P of
/// them over threads. A restarted worker initializes `seq` to
/// `2 * start_iter` so tokens reloaded from a checkpoint (which carry
/// their true global iteration) pass the phase gate unchanged.
pub(crate) struct Worker<'a> {
    pub(crate) id: usize,
    pub(crate) p: usize,
    pub(crate) ntok: usize,
    pub(crate) n_total: usize,
    pub(crate) t_max: u32,
    pub(crate) k: usize,
    /// Padded factor stride (`padded_k(k)`): the row stride of `aa`,
    /// `acc_a`, `acc_s2` and of every token's factor payload.
    pub(crate) kp: usize,
    /// The column-block grid tokens are cut from (block size C over D).
    pub(crate) col_plan: ColPartition,
    pub(crate) task: Task,
    pub(crate) eta: LrSchedule,
    pub(crate) lambda_w: f32,
    pub(crate) lambda_v: f32,
    /// Labels of the local row shard (moved out of the
    /// [`partition::Shard`] this worker was built from).
    pub(crate) labels: Vec<f32>,
    pub(crate) cols: Csc,
    pub(crate) nloc: usize,
    /// Auxiliary variables (paper's G and A) for the local rows; `aa` is
    /// `nloc x kp` lane-blocked (padding lanes zero).
    pub(crate) g: Vec<f32>,
    pub(crate) aa: Vec<f32>,
    /// Recompute-phase partial-sum accumulators (`acc_a`/`acc_s2` are
    /// `nloc x kp` lane-blocked).
    pub(crate) acc_xw: Vec<f32>,
    pub(crate) acc_a: Vec<f32>,
    pub(crate) acc_s2: Vec<f32>,
    /// Local copy of the bias (refreshed whenever the bias token passes).
    pub(crate) w0: f32,
    /// Phase gating.
    pub(crate) seq: u64,
    pub(crate) seen: usize,
    pub(crate) holdback: Vec<Token>,
    /// Per-iteration regularizer contributions of tokens this worker flips.
    pub(crate) reg_w: f64,
    pub(crate) reg_v: f64,
    /// Local loss of the last finalize.
    pub(crate) post_tx: Sender<FinalizePost>,
    pub(crate) shared: &'a Shared<'a>,
    pub(crate) visits_processed: u64,
    pub(crate) coords_applied: u64,
    pub(crate) update_mode: super::UpdateMode,
    pub(crate) rng: Pcg64,
    /// Per-worker kernel scratch arena: the column-visit gradient buffer
    /// lives here, so update visits allocate nothing at any K.
    pub(crate) scratch: Scratch,
    /// Deferred recompute payloads: `(block j, offset into def_w, ncols)`
    /// per buffered token, folded into the accumulators in block order at
    /// the end of the phase (see [`Worker::recompute_visit`]).
    pub(crate) def_idx: Vec<(u32, usize, usize)>,
    pub(crate) def_w: Vec<f32>,
    pub(crate) def_v: Vec<f32>,
    /// Per-epoch block checkpoint stream (multi-process runtime only).
    pub(crate) ckpt: Option<CkptHook>,
}

impl<'a> Worker<'a> {
    fn cur_iter(&self) -> u32 {
        (self.seq / 2) as u32
    }

    /// The iteration at which this run ends: `t_max`, or the agreed early
    /// stop when the observer asked to stop.
    fn stop_iter(&self) -> u32 {
        self.t_max
            .min(self.shared.stop_at.load(Ordering::Relaxed))
    }

    pub(crate) fn run(&mut self) {
        loop {
            if self.shared.done.load(Ordering::Relaxed) {
                self.flush_stats();
                return;
            }
            let tok = match self.pop_holdback() {
                Some(t) => t,
                None => match self
                    .shared
                    .transport
                    .recv_timeout(self.id, Duration::from_millis(20))
                {
                    Some(t) => t,
                    None => continue,
                },
            };
            self.handle(tok);
        }
    }

    fn flush_stats(&self) {
        self.shared
            .update_visits
            .fetch_add(self.visits_processed, Ordering::Relaxed);
        self.shared
            .coordinate_updates
            .fetch_add(self.coords_applied, Ordering::Relaxed);
        // Thread CPU time: excludes blocking waits and (crucially, on hosts
        // with fewer cores than workers) preemption by sibling workers.
        self.shared.busy_secs.lock().unwrap()[self.id] =
            crate::util::timer::thread_cpu_secs();
    }

    fn pop_holdback(&mut self) -> Option<Token> {
        let seq = self.seq;
        let pos = self.holdback.iter().position(|t| t.seq() == seq)?;
        Some(self.holdback.swap_remove(pos))
    }

    fn handle(&mut self, mut tok: Token) {
        // Terminal state: training iterations exhausted (or early stop
        // agreed) — collect.
        if self.cur_iter() >= self.stop_iter() {
            debug_assert_eq!(tok.iter, self.stop_iter());
            self.shared.collector.lock().unwrap().push(tok);
            self.shared.collected.fetch_add(1, Ordering::SeqCst);
            return;
        }
        let cur = self.seq;
        let ts = tok.seq();
        if ts > cur {
            // Invariant 2: ahead by exactly one phase.
            debug_assert!(ts == cur + 1, "token seq {ts} vs worker {cur}");
            self.holdback.push(tok);
            // fetch_max: a load-then-store here would let concurrent
            // workers overwrite a larger peak with a smaller one.
            self.shared
                .holdback_peak
                .fetch_max(self.holdback.len(), Ordering::Relaxed);
            return;
        }
        debug_assert!(ts == cur, "token behind worker: {ts} < {cur}");

        match tok.phase {
            Phase::Update => self.update_visit(&mut tok),
            Phase::Recompute => self.recompute_visit(&tok),
        }
        tok.visits += 1;

        if tok.visits as usize == self.p {
            // Last visitor: publish (recompute pass only) and flip.
            if tok.phase == Phase::Recompute {
                if tok.is_bias() {
                    if let Some(m) = self.shared.mirror {
                        m.publish_bias(tok.w[0]);
                    }
                } else {
                    let (lo, _hi) = self.block_range(tok.j);
                    let (k, kp) = (self.k, self.kp);
                    for (bi, &wj) in tok.w.iter().enumerate() {
                        // The mirror holds K-strided rows: publish the K
                        // real lanes, stripping the padding at this edge.
                        if let Some(m) = self.shared.mirror {
                            m.publish_column(lo + bi, wj, &tok.vrow(bi, kp)[..k]);
                        }
                        self.reg_w += (wj as f64) * (wj as f64);
                    }
                    // Padding lanes are identically zero, so summing the
                    // padded payload is the exact ||v_j||^2 sum.
                    self.reg_v += tok.v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>();
                }
            }
            let crossed_epoch = tok.flip();
            // Block-granular checkpointing: the post-flip token *is* the
            // restart state for the epoch boundary just crossed (iteration
            // `tok.iter` not yet run, phase Update, zero visits).
            if crossed_epoch {
                if let Some(h) = &self.ckpt {
                    if tok.iter % h.every.max(1) == 0 {
                        let _ = h.tx.send(CkptMsg::Block(tok.clone()));
                    }
                }
            }
        }
        self.shared.transport.send((self.id + 1) % self.p, tok);

        self.seen += 1;
        if self.seen == self.ntok {
            self.advance_phase();
        }
    }

    /// Paper Algorithm 1 lines 12-17: eqs. 11-13 with cached G and A,
    /// applied as *incremental gradient descent* over the local column
    /// (footnote 2). Because `G` is frozen between recompute passes, the
    /// per-example contributions are partial sums of the eq. 5-normalized
    /// gradient (scaled by 1/N, with the L2 term split across the P
    /// visits): after all P visits of an outer iteration the column has
    /// moved by exactly `-eta * (mean gradient + lambda * column)`. This is
    /// the stable semantics of updating with stale multipliers — applying
    /// eq. 12/13 per-nonzero with frozen G would compound into an
    /// unnormalized batch step and diverge at any practical eta.
    fn update_visit(&mut self, tok: &mut Token) {
        self.visits_processed += 1;
        let eta = self.eta.at(self.cur_iter() as usize);
        let inv_n = 1.0 / self.n_total.max(1) as f32;
        if tok.is_bias() {
            // eq. 11 aggregated over the local block: after all P visits
            // the bias has moved by -eta * mean(G).
            let gsum: f32 = self.g.iter().sum();
            tok.w[0] -= eta * gsum * inv_n;
            self.w0 = tok.w[0];
            return;
        }
        if let super::UpdateMode::Stochastic { samples } = self.update_mode {
            return self.update_visit_stochastic(tok, eta, samples);
        }
        let (lo, hi) = self.block_range(tok.j);
        let kp = self.kp;
        let h = visit::VisitHyper {
            eta,
            inv_n,
            lambda_w: self.lambda_w,
            lambda_v: self.lambda_v,
            reg_split: 1.0 / self.p as f32,
        };
        for (bi, j) in (lo..hi).enumerate() {
            let (rows, xs) = self.cols.col(j);
            self.coords_applied += rows.len() as u64;
            // eq. 12 / eq. 13 over the lane-blocked column, 1/N-normalized,
            // L2 split across the P visits; the gradient buffer lives in
            // the worker's scratch arena, so no visit allocates at any K.
            visit::col_update(
                rows,
                xs,
                &self.g,
                &self.aa,
                kp,
                &mut tok.w[bi],
                &mut tok.v[bi * kp..(bi + 1) * kp],
                h,
                &mut self.scratch,
            );
        }
    }

    /// Columns `[lo, hi)` of block `b` (delegates to the shared grid).
    #[inline]
    fn block_range(&self, b: u32) -> (usize, usize) {
        self.col_plan.block_range(b as usize)
    }

    /// Paper-literal Algorithm 1 line 14 (`UpdateMode::Stochastic`):
    /// sample local examples and apply the per-example eq. 12/13 updates
    /// with the frozen multipliers.
    fn update_visit_stochastic(&mut self, tok: &mut Token, eta: f32, samples: usize) {
        let (lo, hi) = self.block_range(tok.j);
        let kp = self.kp;
        for (bi, j) in (lo..hi).enumerate() {
            let (rows, xs) = self.cols.col(j);
            // Empty columns apply nothing and draw nothing from the RNG.
            let applied = visit::col_update_stochastic(
                rows,
                xs,
                &self.g,
                &self.aa,
                kp,
                &mut tok.w[bi],
                &mut tok.v[bi * kp..(bi + 1) * kp],
                eta,
                self.lambda_w,
                self.lambda_v,
                samples,
                &mut self.rng,
            );
            self.coords_applied += applied;
        }
    }

    /// Algorithm 1 lines 18-21: fold the token into the partial sums for
    /// G and A (incremental synchronization).
    ///
    /// The fold is *deferred*: the payload is buffered here and applied in
    /// block order at the end of the phase ([`Self::apply_deferred`]).
    /// Token arrival order within a phase depends on thread/network timing
    /// once P > 1, and f32 accumulation into `acc_*` does not commute —
    /// deferring and sorting makes the recompute pass (and with it the
    /// whole MeanGradient run) bitwise deterministic at any P, which is
    /// what lets the multi-process ring reproduce the in-process model
    /// exactly. At P = 1 tokens already arrive in block order, so the
    /// sorted fold is the same fold as the old eager one.
    fn recompute_visit(&mut self, tok: &Token) {
        if tok.is_bias() {
            // Order-independent (plain overwrite): keep it eager.
            self.w0 = tok.w[0];
            return;
        }
        let off = self.def_w.len();
        self.def_idx.push((tok.j, off, tok.ncols()));
        self.def_w.extend_from_slice(&tok.w);
        self.def_v.extend_from_slice(&tok.v);
    }

    /// Folds the buffered recompute payloads into `acc_*` in ascending
    /// block order (every block is buffered exactly once per phase).
    fn apply_deferred(&mut self) {
        let mut idx = std::mem::take(&mut self.def_idx);
        idx.sort_unstable_by_key(|&(j, _, _)| j);
        let kp = self.kp;
        for &(j, off, ncols) in &idx {
            let (lo, hi) = self.block_range(j);
            debug_assert_eq!(hi - lo, ncols);
            for (bi, col) in (lo..hi).enumerate() {
                let (rows, xs) = self.cols.col(col);
                visit::col_recompute(
                    rows,
                    xs,
                    self.def_w[off + bi],
                    &self.def_v[(off + bi) * kp..(off + bi + 1) * kp],
                    kp,
                    &mut self.acc_xw,
                    &mut self.acc_a,
                    &mut self.acc_s2,
                );
            }
        }
        idx.clear();
        self.def_idx = idx;
        self.def_w.clear();
        self.def_v.clear();
    }

    fn advance_phase(&mut self) {
        if self.seq % 2 == 1 {
            self.apply_deferred();
            self.finalize();
        }
        self.seq += 1;
        self.seen = 0;
        // Bounded pipelining: never enter an iteration's update phase more
        // than two iterations ahead of the driver's aggregation (see
        // `Shared::driver_iters`). The `Acquire` load pairs with the
        // driver's `Release` publish, so once the gate opens this worker
        // also sees any `stop_at` the driver set beforehand.
        if self.seq % 2 == 0 {
            let iter = (self.seq / 2) as u32;
            loop {
                let published = self.shared.driver_iters.load(Ordering::Acquire);
                if iter <= published.saturating_add(2)
                    || iter >= self.stop_iter()
                    || self.shared.done.load(Ordering::Relaxed)
                {
                    break;
                }
                std::thread::sleep(Duration::from_micros(200));
            }
        }
    }

    /// End of a recompute pass: rebuild G and A from the partial sums,
    /// report the local loss + regularizer contributions.
    fn finalize(&mut self) {
        let iter = (self.seq / 2) as u32;
        let loss_sum = visit::finalize_rows(
            self.w0,
            &self.acc_xw,
            &self.acc_a,
            &self.acc_s2,
            self.kp,
            &self.labels,
            self.task,
            &mut self.g,
        );
        self.aa.copy_from_slice(&self.acc_a);
        self.acc_xw.fill(0.0);
        self.acc_a.fill(0.0);
        self.acc_s2.fill(0.0);
        let _ = self.post_tx.send(FinalizePost {
            iter,
            loss_sum,
            n_local: self.nloc,
            reg_w: std::mem::take(&mut self.reg_w),
            reg_v: std::mem::take(&mut self.reg_v),
        });
        // Every block this worker flipped at this epoch boundary was sent
        // before its forwarding `send` — and forwarding precedes the
        // `seen == ntok` phase advance that runs this finalize — so the
        // marker strictly follows all of its blocks in the channel.
        if let Some(h) = &self.ckpt {
            let next = iter + 1;
            if next % h.every.max(1) == 0 {
                let _ = h.tx.send(CkptMsg::EpochDone(next));
            }
        }
    }
}

/// The deal: token -> initial owner rank, reproduced identically by every
/// process from `(seed, p)` alone (Algorithm 1 l.5-8). Entry `b` is the
/// owner of block `b`; the last entry owns the bias token.
pub(crate) fn deal_ranks(ntok: usize, seed: u64, p: usize) -> Vec<usize> {
    let mut deal_rng = Pcg64::new(seed, 0xdea1);
    (0..ntok).map(|_| deal_rng.below_usize(p)).collect()
}

/// Cuts a model into ring tokens (blocks in ascending order, bias last),
/// with lane-padded factor payloads from the kernel's AoSoA view. Tokens
/// carry `start_iter` so a checkpoint-restarted ring resumes the learning
/// rate schedule at the true global iteration.
pub(crate) fn deal_tokens(
    init: &FmModel,
    init_kernel: &FmKernel,
    col_plan: &ColPartition,
    start_iter: u32,
) -> Vec<Token> {
    let nblocks = col_plan.n_blocks();
    let mut toks = Vec::with_capacity(nblocks + 1);
    for b in 0..nblocks {
        let (lo, hi) = col_plan.block_range(b);
        toks.push(Token {
            j: b as u32,
            iter: start_iter,
            phase: Phase::Update,
            visits: 0,
            w: Box::from(&init.w[lo..hi]),
            v: Box::from(init_kernel.vrows_padded(lo, hi)),
        });
    }
    toks.push(Token {
        j: BIAS,
        iter: start_iter,
        phase: Phase::Update,
        visits: 0,
        w: Box::from([init.w0]),
        v: Box::from([]),
    });
    toks
}

/// Exact initial G/A for one shard, scored through the fused kernel from
/// `kern` (the model the ring starts or restarts from). The `aa` arena is
/// `nloc x kp` lane-blocked; padding lanes stay zero from init.
pub(crate) fn seed_arenas(
    shard: &partition::Shard,
    kern: &FmKernel,
    k: usize,
) -> (partition::ShardArenas, Scratch) {
    let kp = padded_k(k);
    let mut scratch = Scratch::for_k(k);
    let mut arenas = shard.arenas(k);
    for r in 0..shard.nloc() {
        let (idx, val) = shard.rows.row(r);
        let f = kern.score_with_sums(idx, val, &mut arenas.aa[r * kp..r * kp + k], &mut scratch);
        arenas.g[r] = loss::multiplier(f, shard.labels[r], shard.task);
    }
    (arenas, scratch)
}

/// Exact model assembly from one full set of tokens (invariant 4): every
/// block exactly once, every token at `expect_iter`, padding stripped back
/// to the K-strided model. Shared by the in-process engine, the cluster
/// driver's final assembly, and checkpoint restore.
pub(crate) fn assemble_model(
    tokens: Vec<Token>,
    col_plan: &ColPartition,
    d: usize,
    k: usize,
    expect_iter: u32,
) -> Result<FmModel> {
    let kp = padded_k(k);
    let nblocks = col_plan.n_blocks();
    let ntok = nblocks + 1;
    ensure!(
        tokens.len() == ntok,
        "collector has {} tokens, want {ntok}",
        tokens.len()
    );
    let mut model = FmModel::zeros(d, k);
    let mut seen_bias = false;
    let mut seen_blocks = vec![false; nblocks];
    for tok in tokens {
        ensure!(
            tok.iter == expect_iter,
            "token finished at iter {}, want {expect_iter}",
            tok.iter
        );
        if tok.is_bias() {
            ensure!(!seen_bias, "duplicate bias token");
            seen_bias = true;
            model.w0 = tok.w[0];
        } else {
            let b = tok.j as usize;
            ensure!(b < nblocks, "token block {b} out of range");
            ensure!(!seen_blocks[b], "duplicate token for block {b}");
            seen_blocks[b] = true;
            let (lo, hi) = col_plan.block_range(b);
            ensure!(tok.w.len() == hi - lo, "block {b} width mismatch");
            ensure!(
                tok.v.len() == (hi - lo) * kp,
                "block {b} padded payload mismatch: {} vs {}",
                tok.v.len(),
                (hi - lo) * kp
            );
            model.w[lo..hi].copy_from_slice(&tok.w);
            // Strip the padding lanes: the model is K-strided.
            for (bi, j) in (lo..hi).enumerate() {
                model.v[j * k..(j + 1) * k].copy_from_slice(&tok.vrow(bi, kp)[..k]);
            }
        }
    }
    ensure!(seen_bias, "bias token missing");
    ensure!(
        seen_blocks.iter().all(|&s| s),
        "missing column-block tokens after drain"
    );
    Ok(model)
}

/// Runs DS-FACTO over an arbitrary transport. Returns the trained model,
/// trace and engine counters. Every completed outer iteration is reported
/// to `obs`; a [`ControlFlow::Stop`](crate::train::ControlFlow) request is
/// honored within at most three further outer iterations (the in-flight
/// pipeline depth of the decentralized protocol) while preserving exact
/// token finalization. `obs.on_done` is left to the caller.
pub fn train_with_transport(
    train: &Dataset,
    test: Option<&Dataset>,
    fm: &FmHyper,
    cfg: &NomadConfig,
    transport: &dyn Transport,
    obs: &mut dyn TrainObserver,
) -> Result<(TrainOutput, EngineStats)> {
    train_with_transport_data(EngineData::Memory { train, test }, fm, cfg, transport, obs)
}

/// [`train_with_transport`] off a [`DataSource`]: shards are pulled
/// straight from the source (ignoring `cfg.source`), the per-iteration
/// objective comes from the workers' exact finalize posts as always, and
/// the iter-0 point is computed with
/// [`streaming_objective`](crate::train::streaming_objective) — so no
/// step of the run materializes the full matrix. There is no held-out
/// set (a streaming run has none); evaluate afterwards with
/// [`streaming_eval`](crate::train::streaming_eval).
///
/// [`DataSource`]: crate::data::DataSource
pub fn train_from_source_with_transport(
    src: &dyn crate::data::DataSource,
    fm: &FmHyper,
    cfg: &NomadConfig,
    transport: &dyn Transport,
    obs: &mut dyn TrainObserver,
) -> Result<(TrainOutput, EngineStats)> {
    train_with_transport_data(EngineData::Stream { src }, fm, cfg, transport, obs)
}

/// What feeds a training run: the borrowed in-memory pair, or a
/// [`DataSource`](crate::data::DataSource) streamed shard by shard.
enum EngineData<'a> {
    Memory {
        train: &'a Dataset,
        test: Option<&'a Dataset>,
    },
    Stream {
        src: &'a dyn crate::data::DataSource,
    },
}

fn train_with_transport_data(
    data: EngineData<'_>,
    fm: &FmHyper,
    cfg: &NomadConfig,
    transport: &dyn Transport,
    obs: &mut dyn TrainObserver,
) -> Result<(TrainOutput, EngineStats)> {
    let (n, d) = match &data {
        EngineData::Memory { train, .. } => (train.n(), train.d()),
        EngineData::Stream { src } => (src.n(), src.d()),
    };
    ensure!(n > 0, "empty training set");
    ensure!(d > 0, "zero-dimensional training set");
    let test = match &data {
        EngineData::Memory { test, .. } => *test,
        EngineData::Stream { .. } => None,
    };
    let p = cfg.workers.max(1);
    let k = fm.k;
    let kp = padded_k(k);
    // Column-block grid: the granularity optimization (EXPERIMENTS.md
    // §Perf). 0 = auto heuristic.
    let col_plan = if cfg.cols_per_token == 0 {
        ColPartition::auto(d, p)
    } else {
        ColPartition::with_block_size(d, cfg.cols_per_token)
    };
    let nblocks = col_plan.n_blocks();
    let ntok = nblocks + 1; // + bias token
    let t_max = cfg.outer_iters as u32;

    // Row-shard plan (contiguous by default — identical to the legacy
    // chunking; `balanced` equalizes per-shard nnz on row-skewed data),
    // computed through the data seam: the in-memory source plans off the
    // training CSR exactly as before, a shard cache returns the plan its
    // files were cut on.
    let resolved;
    let source: &dyn crate::data::DataSource = match &data {
        EngineData::Memory { train, .. } => {
            resolved = cfg.source.resolve(train)?;
            resolved.as_dyn()
        }
        EngineData::Stream { src } => *src,
    };
    let row_plan = source.plan(cfg.row_partition, p)?;
    let pstats = match &data {
        EngineData::Memory { train, .. } => PartitionStats::from_plan(&row_plan, &train.rows),
        // No full CSR exists to measure: the cache manifest carries the
        // per-shard nnz; a hint-less source reports the unmeasured default.
        EngineData::Stream { src } => src
            .shard_nnz_hint(&row_plan)
            .map(PartitionStats::from_shard_nnz)
            .unwrap_or_default(),
    };

    // ---- Initial model and auxiliary variables (exact, pre-launch).
    let mut rng = Pcg64::new(cfg.seed, 0x0ad);
    let init = FmModel::init(d, k, fm.init_std, &mut rng);
    let mirror = ParamMirror::new(&init);
    // Lane-blocked view shared by every worker's initial G/A pass.
    let init_kernel = FmKernel::from_model(&init);

    let (post_tx, post_rx) = channel::<FinalizePost>();
    let shared = Shared {
        transport,
        mirror: Some(&mirror),
        collector: Mutex::new(Vec::with_capacity(ntok)),
        collected: AtomicUsize::new(0),
        done: AtomicBool::new(false),
        update_visits: AtomicU64::new(0),
        coordinate_updates: AtomicU64::new(0),
        holdback_peak: AtomicUsize::new(0),
        busy_secs: Mutex::new(vec![0.0; p]),
        stop_at: AtomicU32::new(u32::MAX),
        driver_iters: AtomicU32::new(0),
    };

    // ---- Initial point (iter 0 = before training), computed exactly and
    // reported before any token moves so a Stop costs nothing.
    let mut trace: Vec<TracePoint> = Vec::with_capacity(cfg.outer_iters + 1);
    {
        let pt0 = match &data {
            EngineData::Memory { train, test } => {
                crate::train::trace_point(train, *test, fm.lambda_w, fm.lambda_v, 0, 0.0, &init)
            }
            EngineData::Stream { src } => crate::train::streaming_trace_point(
                *src,
                &row_plan,
                &init,
                fm.lambda_w,
                fm.lambda_v,
                0,
                0.0,
            )?,
        };
        let flow = obs.on_iter(&pt0, Some(&init));
        trace.push(pt0);
        if flow.is_stop() {
            return Ok((
                TrainOutput {
                    model: init,
                    trace,
                    wall_secs: 0.0,
                },
                EngineStats {
                    worker_busy_secs: vec![0.0; p],
                    partition: pstats,
                    ..EngineStats::default()
                },
            ));
        }
    }

    // Materialize the per-worker shards (local CSR + CSC + labels)
    // through the one shared parallel build path — a pool capped at
    // `available_parallelism`; with a cache source each load reads only
    // that worker's shard file.
    let shards = partition::build_shards_from_source(source, &row_plan)?;

    // ---- Seed the ring: deal tokens across workers (Algorithm 1 l.5-8).
    // Factor payloads are dealt lane-padded (`ncols x kp`) straight from
    // the kernel's AoSoA view; the wire codec strips the padding back to
    // the K-strided frame at serialization boundaries.
    {
        let ranks = deal_ranks(ntok, cfg.seed, p);
        for (tok, &dst) in deal_tokens(&init, &init_kernel, &col_plan, 0)
            .into_iter()
            .zip(&ranks)
        {
            transport.send(dst, tok);
        }
    }

    let sw = Stopwatch::start();

    let stats = std::thread::scope(|scope| -> Result<EngineStats> {
        let shared_ref = &shared;
        let mut handles = Vec::with_capacity(p);
        for shard in shards {
            let post_tx = post_tx.clone();
            let init_ref = &init;
            let init_kern = &init_kernel;
            handles.push(scope.spawn(move || {
                let nloc = shard.nloc();
                // Exact initial G/A from the init model, scored through the
                // shared fused kernel with this worker's scratch arena.
                let (arenas, scratch) = seed_arenas(&shard, init_kern, k);
                let partition::Shard {
                    id,
                    task,
                    cols,
                    labels,
                    ..
                } = shard;
                let mut w = Worker {
                    id,
                    p,
                    ntok,
                    n_total: n,
                    t_max,
                    k,
                    kp,
                    col_plan,
                    task,
                    eta: cfg.eta,
                    lambda_w: fm.lambda_w,
                    lambda_v: fm.lambda_v,
                    labels,
                    cols,
                    nloc,
                    g: arenas.g,
                    aa: arenas.aa,
                    acc_xw: arenas.acc_xw,
                    acc_a: arenas.acc_a,
                    acc_s2: arenas.acc_s2,
                    w0: init_ref.w0,
                    seq: 0,
                    seen: 0,
                    holdback: Vec::new(),
                    reg_w: 0.0,
                    reg_v: 0.0,
                    post_tx,
                    shared: shared_ref,
                    visits_processed: 0,
                    coords_applied: 0,
                    update_mode: cfg.update_mode,
                    rng: Pcg64::new(cfg.seed, 0x3a17 + id as u64),
                    scratch,
                    def_idx: Vec::new(),
                    def_w: Vec::new(),
                    def_v: Vec::new(),
                    ckpt: None,
                };
                w.run();
            }));
        }
        drop(post_tx);

        // ---- Driver: aggregate finalize posts into the trace and report
        // each completed iteration to the observer.
        let mut pending: HashMap<u32, (usize, f64, f64, f64)> = HashMap::new();
        let mut iters_done = 0u32;
        let mut stopping = false;
        while iters_done < t_max.min(shared.stop_at.load(Ordering::Acquire)) {
            match post_rx.recv_timeout(Duration::from_millis(200)) {
                Ok(post) => {
                    let e = pending.entry(post.iter).or_insert((0, 0.0, 0.0, 0.0));
                    e.0 += 1;
                    e.1 += post.loss_sum;
                    e.2 += post.reg_w;
                    e.3 += post.reg_v;
                    debug_assert!(post.n_local <= n);
                    if e.0 == p {
                        let (_, loss_sum, reg_w, reg_v) = pending.remove(&post.iter).unwrap();
                        let train_loss = loss_sum / n as f64;
                        let objective = train_loss
                            + 0.5 * fm.lambda_w as f64 * reg_w
                            + 0.5 * fm.lambda_v as f64 * reg_v;
                        let iter1 = post.iter as usize + 1;
                        iters_done += 1;
                        // Publish progress BEFORE the (possibly slow)
                        // snapshot/eval/observer work below, so worker
                        // pipelining is gated on aggregation only, never on
                        // single-threaded evaluation. Any stop decided below
                        // is stored before the driver aggregates the next
                        // iteration — i.e. before the gate can open further —
                        // so workers that pass the gate still see it.
                        shared.driver_iters.store(iters_done, Ordering::Release);
                        let eval_due = test.is_some() && iter1 % cfg.eval_every.max(1) == 0;
                        // Mirror snapshots cost O(D*K): only materialize one
                        // when this iteration evaluates or an observer asks.
                        let snapshot = (eval_due || obs.wants_model(iter1))
                            .then(|| mirror.snapshot());
                        let test_metrics = match (test, &snapshot) {
                            (Some(ts), Some(m)) if eval_due => Some(evaluate(m, ts)),
                            _ => None,
                        };
                        let pt = TracePoint {
                            iter: iter1,
                            secs: sw.secs(),
                            objective,
                            train_loss,
                            test: test_metrics,
                        };
                        // Observers see every recorded point, including the
                        // <=3 drain-window points after a Stop (whose return
                        // values are ignored), so streamed artifacts always
                        // match the returned trace.
                        let flow = obs.on_iter(&pt, snapshot.as_ref());
                        if !stopping && flow.is_stop() {
                            stopping = true;
                            // Tokens are provably at most at iteration
                            // post.iter + 4's update phase (pipeline bound
                            // of 2 past the just-published count, + one
                            // phase of token lead): collect there.
                            shared
                                .stop_at
                                .fetch_min(post.iter.saturating_add(4), Ordering::SeqCst);
                        }
                        trace.push(pt);
                    }
                }
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("all workers exited before training completed")
                }
            }
        }

        // ---- Drain: wait for every token to land in the collector.
        let drain = Stopwatch::start();
        while shared.collected.load(Ordering::SeqCst) < ntok {
            std::thread::sleep(Duration::from_millis(1));
            ensure!(
                drain.secs() < 60.0,
                "token drain timed out: {}/{} collected",
                shared.collected.load(Ordering::SeqCst),
                ntok
            );
        }
        shared.done.store(true, Ordering::SeqCst);
        for h in handles {
            h.join().map_err(|_| anyhow::anyhow!("worker panicked"))?;
        }
        Ok(EngineStats {
            messages: 0,
            bytes: 0,
            update_visits: shared.update_visits.load(Ordering::Relaxed),
            coordinate_updates: shared.coordinate_updates.load(Ordering::Relaxed),
            holdback_peak: shared.holdback_peak.load(Ordering::Relaxed),
            worker_busy_secs: shared.busy_secs.lock().unwrap().clone(),
            partition: pstats.clone(),
        })
    })?;

    let wall = sw.secs();

    // ---- Exact final model from the collected tokens (invariant 4). An
    // early-stopped run finalizes at the agreed stop iteration instead of
    // t_max; either way every token carries the same iteration.
    let stopped_at = t_max.min(shared.stop_at.load(Ordering::Acquire));
    let tokens = shared.collector.into_inner().unwrap();
    let model = assemble_model(tokens, &col_plan, d, k, stopped_at)?;

    let tstats = transport.stats();
    let mut stats = stats;
    stats.messages = tstats.messages;
    stats.bytes = tstats.bytes;

    trace.sort_by_key(|pt| pt.iter);
    Ok((
        TrainOutput {
            model,
            trace,
            wall_secs: wall,
        },
        stats,
    ))
}

/// Context binding for anyhow (keeps the public signature tidy).
pub(super) fn run(
    train: &Dataset,
    test: Option<&Dataset>,
    fm: &FmHyper,
    cfg: &NomadConfig,
    transport: &dyn Transport,
    obs: &mut dyn TrainObserver,
) -> Result<(TrainOutput, EngineStats)> {
    train_with_transport(train, test, fm, cfg, transport, obs)
        .context("DS-FACTO engine run failed")
}

/// [`run`] for the streaming path.
pub(super) fn run_from_source(
    src: &dyn crate::data::DataSource,
    fm: &FmHyper,
    cfg: &NomadConfig,
    transport: &dyn Transport,
    obs: &mut dyn TrainObserver,
) -> Result<(TrainOutput, EngineStats)> {
    train_from_source_with_transport(src, fm, cfg, transport, obs)
        .context("DS-FACTO engine run failed")
}
