//! Parameter tokens: the unit of circulation in DS-FACTO.
//!
//! A token owns a **block of parameter columns** `{w_j, v_j : j in block}`
//! (paper Fig. 3 circulates single columns; blocking is the granularity
//! optimization NOMAD applies in practice — per-visit queue/dispatch
//! overhead is paid once per *block* instead of once per column, which is
//! what lets wide models like realsim scale; see EXPERIMENTS.md §Perf).
//! Exactly one worker holds a token at any instant — this ownership
//! invariant is what makes the engine lock-free on parameters. A special
//! **bias token** carries `w0`.
//!
//! Each outer iteration a token makes two full ring passes:
//! * [`Phase::Update`]   — each worker applies eqs. 12-13 against its row
//!   block (eq. 11 for the bias token);
//! * [`Phase::Recompute`] — each worker folds the token's (fresh) values
//!   into its partial sums for the auxiliary variables G and A
//!   (the paper's *incremental synchronization*, §4.2).
//!
//! After `P` visits in a phase the last visitor flips the token to the next
//! phase (Update -> Recompute -> next iteration's Update).
//!
//! ## Factor payload layout
//!
//! `Token` itself is stride-agnostic: `v` is `ncols x stride` row-major
//! for whatever stride the producer chose. The engine circulates tokens
//! **lane-padded** (`stride = padded_k(k)`, padding lanes invariantly
//! zero) so every visit runs the lane-blocked kernels in
//! [`crate::kernel::visit`] directly on the payload; the wire codec
//! (`cluster::codec::{encode_token_padded, decode_token_padded}`) strips
//! to / re-pads from the K-strided wire form, which is byte-identical to
//! the unpadded era. Hand-built K-strided tokens (tests, oracles) remain
//! valid with `stride = k`.
//!
//! The cluster ring can additionally carry the K-strided payload in
//! **bf16** (`wire_precision = bf16`: every `w` and `v` value travels as
//! the top 16 bits of its f32, halving the payload bytes per hop). That
//! is purely a property of the socket encoding —
//! `cluster::codec::{encode_token_bf16, decode_token_bf16}` convert at
//! the transport seam, and the in-memory `Token` is always full f32.

/// Block id of the bias token (carries `w0`).
pub const BIAS: u32 = u32::MAX;

/// Which ring pass the token is on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Parameter-update pass (paper Algorithm 1, lines 12-17).
    Update,
    /// G/A recomputation pass (Algorithm 1, lines 18-21).
    Recompute,
}

/// A circulating block of parameter columns.
#[derive(Debug, Clone, PartialEq)]
pub struct Token {
    /// Column-block id (block `b` covers columns `[b*C, min((b+1)*C, D))`
    /// for block size C), or [`BIAS`].
    pub j: u32,
    /// Outer iteration the token is currently in.
    pub iter: u32,
    /// Current ring pass.
    pub phase: Phase,
    /// Completed worker visits in the current phase.
    pub visits: u16,
    /// Linear weights `w_j` for the block's columns (length = #cols;
    /// length 1 holding `w0` for the bias token).
    pub w: Box<[f32]>,
    /// Factor rows `v_j`, row-major `#cols x stride` (empty for bias).
    /// The engine uses `stride = padded_k(K)` (lane-padded, zero padding);
    /// the wire form uses `stride = K`. See the module docs.
    pub v: Box<[f32]>,
}

impl Token {
    /// True for the bias token.
    #[inline]
    pub fn is_bias(&self) -> bool {
        self.j == BIAS
    }

    /// Number of columns this token carries.
    #[inline]
    pub fn ncols(&self) -> usize {
        if self.is_bias() {
            0
        } else {
            self.w.len()
        }
    }

    /// Factor row `bi` of the payload at the given row stride (the
    /// engine passes `padded_k(k)`; K-strided producers pass `k`). The
    /// update-phase kernels slice `v` directly instead, because they need
    /// `&mut v[..]` and `&mut w[bi]` simultaneously (disjoint field
    /// borrows a `&mut self` method cannot express).
    #[inline]
    pub fn vrow(&self, bi: usize, stride: usize) -> &[f32] {
        &self.v[bi * stride..(bi + 1) * stride]
    }

    /// Total phase sequence number: tokens and workers advance through
    /// `seq = 2*iter + (phase == Recompute)` in lockstep (+/- 1).
    #[inline]
    pub fn seq(&self) -> u64 {
        2 * self.iter as u64
            + match self.phase {
                Phase::Update => 0,
                Phase::Recompute => 1,
            }
    }

    /// Advances to the next phase; returns true if a new iteration started.
    pub fn flip(&mut self) -> bool {
        self.visits = 0;
        match self.phase {
            Phase::Update => {
                self.phase = Phase::Recompute;
                false
            }
            Phase::Recompute => {
                self.phase = Phase::Update;
                self.iter += 1;
                true
            }
        }
    }
}

/// Block size heuristic: keep ~64 tokens in flight per worker so the
/// ring stays busy while per-visit dispatch overhead amortizes over many
/// columns. The implementation lives with the partition plans
/// ([`crate::partition::auto_block_cols`]); this re-export keeps the
/// token-facing spelling.
pub fn auto_block_cols(d: usize, p: usize) -> usize {
    crate::partition::auto_block_cols(d, p)
}

/// Number of circulating tokens (column blocks + bias) for a model with
/// `d` columns at block size `c`.
pub fn n_tokens(d: usize, c: usize) -> usize {
    d.div_ceil(c.max(1)) + 1
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok() -> Token {
        Token {
            j: 3,
            iter: 0,
            phase: Phase::Update,
            visits: 0,
            w: vec![0.0; 4].into_boxed_slice(),
            v: vec![0.0; 8].into_boxed_slice(),
        }
    }

    #[test]
    fn seq_orders_phases() {
        let mut t = tok();
        assert_eq!(t.seq(), 0);
        t.flip();
        assert_eq!(t.seq(), 1);
        assert_eq!(t.iter, 0);
        let new_iter = t.flip();
        assert!(new_iter);
        assert_eq!(t.seq(), 2);
        assert_eq!(t.iter, 1);
        assert_eq!(t.phase, Phase::Update);
    }

    #[test]
    fn flip_resets_visits() {
        let mut t = tok();
        t.visits = 7;
        assert!(!t.flip());
        assert_eq!(t.visits, 0);
    }

    #[test]
    fn vrow_slices_by_stride() {
        let mut t = tok(); // 4 cols, v.len() == 8 -> stride 2
        t.v[2] = 7.0;
        assert_eq!(t.vrow(1, 2), &[7.0, 0.0]);
        assert_eq!(t.vrow(3, 2), &[0.0, 0.0]);
    }

    #[test]
    fn bias_token_detection() {
        let mut t = tok();
        assert!(!t.is_bias());
        assert_eq!(t.ncols(), 4);
        t.j = BIAS;
        assert!(t.is_bias());
        assert_eq!(t.ncols(), 0);
    }

    #[test]
    fn auto_block_scales_with_width() {
        assert_eq!(auto_block_cols(22, 4), 1);
        assert_eq!(auto_block_cols(20_958, 8), 40);
        assert!(auto_block_cols(1, 32) >= 1);
    }

    #[test]
    fn token_counts() {
        assert_eq!(n_tokens(10, 1), 11);
        assert_eq!(n_tokens(10, 3), 5); // 4 blocks + bias
        assert_eq!(n_tokens(10, 100), 2);
    }
}
