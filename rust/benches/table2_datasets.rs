//! Table 2 reproduction: dataset characteristics.
//!
//! Prints the paper's Table 2 rows next to what the synthetic twins
//! actually produce (N, D, K, task, plus measured density and generation
//! time). Run: `cargo bench --bench table2_datasets`.

use dsfacto::data::synth::{generate, SynthSpec};
use dsfacto::util::timer::Stopwatch;

fn main() -> anyhow::Result<()> {
    println!("== Table 2: Dataset Characteristics (paper vs synthetic twin) ==\n");
    println!(
        "{:<10} {:>8} {:>8} {:>4} {:<15} {:>10} {:>10} {:>9}",
        "dataset", "N", "D", "K", "task", "nnz", "density", "gen-secs"
    );
    for name in SynthSpec::table2_names() {
        let spec = SynthSpec::table2(name)?;
        let sw = Stopwatch::start();
        let out = generate(&spec, 42);
        let secs = sw.secs();
        let ds = out.dataset;
        ds.validate()?;
        // Paper's Table 2 values are the spec itself; assert the twin hits
        // them exactly.
        assert_eq!(ds.n(), spec.n, "{name}: N mismatch");
        assert_eq!(ds.d(), spec.d, "{name}: D mismatch");
        println!(
            "{:<10} {:>8} {:>8} {:>4} {:<15} {:>10} {:>9.4}% {:>9.2}",
            name,
            ds.n(),
            ds.d(),
            spec.k,
            spec.task.name(),
            ds.nnz(),
            100.0 * ds.density(),
            secs
        );
    }
    println!(
        "\npaper Table 2: diabetes 513x8 K4, housing 303x13 K4, ijcnn1 49990x22 K4,\n\
         realsim 50616x20958 K16 — all matched by construction (DESIGN.md §2)."
    );
    Ok(())
}
