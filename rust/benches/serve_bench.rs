//! Serving-path latency/throughput report (EXPERIMENTS.md §Serve): an
//! in-process `dsfacto serve` instance on loopback, driven at 1, 8 and
//! 64 concurrent client streams, unbatched (synchronous single-row
//! requests) vs batched (pipelined 16-request bursts the server gathers
//! into fused sweeps).
//!
//! Run: `cargo bench --bench serve_bench`.
//!
//! Writes `BENCH_serve.json` (override with `BENCH_JSON`) with, per
//! `(streams, mode)` cell, `p50_ns` / `p99_ns` per-request latency and
//! `rows_per_sec` aggregate throughput — the p50/p99 trajectory CI
//! uploads from the bench-smoke job. `BENCH_SAMPLES` scales the
//! per-stream request count for smoke runs.

use std::time::{Duration, Instant};

use dsfacto::data::synth;
use dsfacto::fm::{io as fm_io, FmModel};
use dsfacto::serve::{serve, ScoreClient, ServeOptions};
use dsfacto::util::bench::{section, BenchReport};
use dsfacto::util::rng::Pcg64;
use dsfacto::util::stats::percentile;

const BURST: usize = 16;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// One client stream's share of the load. Returns per-request latency
/// samples in seconds and the number of rows it scored.
fn drive_stream(
    addr: &str,
    rows: &[(&[u32], &[f32])],
    iters: usize,
    batched: bool,
) -> anyhow::Result<(Vec<f64>, usize)> {
    let mut client = ScoreClient::connect(addr)?;
    let mut lat = Vec::with_capacity(iters * if batched { BURST } else { 1 });
    let mut scored = 0usize;
    let mut cursor = 0usize;
    for _ in 0..iters {
        if batched {
            // Pipelined burst: the server coalesces it into fused sweeps;
            // the whole burst's wall clock is amortized over its requests.
            let t0 = Instant::now();
            for _ in 0..BURST {
                client.send_score_request(&rows[cursor % rows.len()..cursor % rows.len() + 1])?;
                cursor += 1;
            }
            for _ in 0..BURST {
                client.recv()?;
            }
            let per_req = t0.elapsed().as_secs_f64() / BURST as f64;
            lat.extend(std::iter::repeat(per_req).take(BURST));
            scored += BURST;
        } else {
            let t0 = Instant::now();
            let row = &rows[cursor % rows.len()..cursor % rows.len() + 1];
            client.score(row)?;
            lat.push(t0.elapsed().as_secs_f64());
            cursor += 1;
            scored += 1;
        }
    }
    Ok((lat, scored))
}

fn main() -> anyhow::Result<()> {
    let samples = env_usize("BENCH_SAMPLES", 20);
    let json_path = std::env::var("BENCH_JSON").unwrap_or_else(|_| "BENCH_serve.json".to_string());
    let mut report = BenchReport::new("serve_bench");

    // Served workload: the housing twin (d=13) under a k=8 model.
    let ds = synth::table2_dataset("housing", 3)?;
    let mut rng = Pcg64::seeded(17);
    let mut model = FmModel::init(ds.d(), 8, 0.3, &mut rng);
    for x in model.w.iter_mut() {
        *x = rng.normal32(0.0, 0.5);
    }
    let rows: Vec<(&[u32], &[f32])> = (0..ds.n()).map(|i| ds.rows.row(i)).collect();

    let dir = std::env::temp_dir().join("dsfacto_serve_bench");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir)?;
    let model_path = dir.join("model.dsfm");
    fm_io::save(&model, &model_path)?;
    let handle = serve(&ServeOptions {
        addr: "127.0.0.1:0".into(),
        model_path,
        col_blocks: 1,
        max_batch: 64,
        batch_window: Duration::from_micros(100),
        reload_poll: Duration::from_secs(3600),
    })?;
    let addr = handle.addr().to_string();
    println!("serve_bench: server on {addr}, {} rows, d={} k=8", ds.n(), ds.d());

    for &streams in &[1usize, 8, 64] {
        for &batched in &[false, true] {
            let mode = if batched { "batched" } else { "unbatched" };
            section(&format!("{streams} stream(s), {mode}"));
            // Scale per-stream work down as streams go up so wall clock
            // stays bounded; floor keeps the percentile sample count sane.
            let iters = (samples * 8 / streams).max(4);
            let t0 = Instant::now();
            let results: Vec<(Vec<f64>, usize)> = std::thread::scope(|scope| {
                let handles: Vec<_> = (0..streams)
                    .map(|_| {
                        let addr = addr.as_str();
                        let rows = rows.as_slice();
                        scope.spawn(move || drive_stream(addr, rows, iters, batched))
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("stream thread").expect("stream I/O"))
                    .collect()
            });
            let wall = t0.elapsed().as_secs_f64();
            let mut lat: Vec<f64> = Vec::new();
            let mut total_rows = 0usize;
            for (l, n) in results {
                lat.extend(l);
                total_rows += n;
            }
            let p50 = percentile(&lat, 50.0) * 1e9;
            let p99 = percentile(&lat, 99.0) * 1e9;
            let rps = total_rows as f64 / wall.max(1e-9);
            println!(
                "  {total_rows} rows in {:.3}s: p50 {:.0} ns, p99 {:.0} ns, {:.0} rows/s",
                wall, p50, p99, rps
            );
            report.record_value(&format!("serve_s{streams}_{mode}_p50_ns"), p50);
            report.record_value(&format!("serve_s{streams}_{mode}_p99_ns"), p99);
            report.record_value(&format!("serve_s{streams}_{mode}_rows_per_sec"), rps);
        }
    }

    handle.shutdown();
    std::fs::remove_dir_all(&dir).ok();
    report.write(&json_path)?;
    println!("\nwrote {json_path} ({} entries)", report.entries.len());
    Ok(())
}
