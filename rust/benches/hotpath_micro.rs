//! Hot-path microbenchmarks (EXPERIMENTS.md §Perf): the building blocks
//! whose cost bounds every trainer — FM scoring (scalar, lane-blocked,
//! and explicit AVX2 kernels), the per-example update (scalar reference
//! vs the fused `score_grad_step`), the engine's column visits, the token
//! codec (including the f32-vs-bf16 wire-bytes pair), and transports.
//!
//! Run: `cargo bench --bench hotpath_micro`.
//!
//! Besides the table on stdout, the run writes the machine-readable
//! `BENCH_hotpath.json` (override the path with `BENCH_JSON`) so the perf
//! trajectory has commit-comparable points; `BENCH_SAMPLES` and
//! `BENCH_MIN_MS` shorten CI smoke runs. Every section runs inside a
//! panic guard: a broken kernel records `null` for its entries instead of
//! truncating the report, so the JSON always carries the full entry set.

use dsfacto::cluster::{codec, LocalTransport, Transport};
use dsfacto::data::synth;
use dsfacto::fm::FmModel;
use dsfacto::kernel::visit::{self, VisitHyper};
use dsfacto::kernel::{padded_k, FmKernel, KernelBackend, Scratch};
use dsfacto::nomad::token::{Phase, Token, BIAS};
use dsfacto::optim::sgd_update_example;
use dsfacto::util::bench::{bench_summary, ratio_str, section, BenchReport};
use dsfacto::util::prop::pad_rows;
use dsfacto::util::rng::Pcg64;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

/// Runs one bench section, catching panics and errors so a single broken
/// kernel (the very thing a perf bisect hunts) cannot take the whole
/// report down: whatever the body failed to record out of `expected` is
/// written as NaN — serialized as JSON `null` — and the run continues to
/// the next section. `BENCH_hotpath.json` therefore always carries every
/// expected entry name, present or not.
fn guard(
    report: &mut BenchReport,
    expected: &[String],
    body: impl FnOnce(&mut BenchReport) -> anyhow::Result<()>,
) {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut *report))) {
        Ok(Ok(())) => {}
        Ok(Err(e)) => eprintln!("  section failed: {e:#}"),
        Err(payload) => {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| (*s).to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".to_string());
            eprintln!("  section panicked: {msg}");
        }
    }
    for name in expected {
        if report.get(name).is_none() {
            eprintln!("  {name}: not recorded, writing null");
            report.record_value(name, f64::NAN);
        }
    }
}

/// `guard` expected-entry lists, spelled once.
fn names(list: &[&str]) -> Vec<String> {
    list.iter().map(|s| s.to_string()).collect()
}

fn main() -> anyhow::Result<()> {
    let samples = env_usize("BENCH_SAMPLES", 20);
    let json_path =
        std::env::var("BENCH_JSON").unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
    let mut report = BenchReport::new("hotpath_micro");
    let mut rng = Pcg64::seeded(1);

    // Shared workloads (plain data setup, outside the panic guards: if
    // these fail nothing downstream is measurable anyway).
    // Dense ijcnn1-like: D=22, K=4.
    let ds = synth::table2_dataset("ijcnn1", 7)?;
    let model = {
        let mut m = FmModel::init(ds.d(), 4, 0.1, &mut rng);
        for x in m.w.iter_mut() {
            *x = rng.normal32(0.0, 0.3);
        }
        m
    };
    // Sparse realsim-like rows: ~52 nnz, K=16.
    let spec = synth::SynthSpec {
        n: 2000,
        ..synth::SynthSpec::table2("realsim")?
    };
    let sparse = synth::generate(&spec, 8).dataset;

    section("FM scoring (eq. 4 rewrite): scalar vs fused kernel, per backend");
    let kern = FmKernel::from_model(&model);
    let mut scratch = Scratch::for_k(4);
    let n = ds.n();
    guard(
        &mut report,
        &names(&[
            "score_sparse dense d=22 k=4",
            "kernel_score dense d=22 k=4",
            "kernel_score dense d=22 k=4 lanes",
            "kernel_score dense d=22 k=4 avx2",
            "score_sparse sparse k=16",
            "kernel_score sparse k=16",
        ]),
        |report| {
            let mut i = 0usize;
            let s = bench_summary("score_sparse dense d=22 k=4 (per example)", samples, || {
                let (idx, val) = ds.rows.row(i % n);
                i += 1;
                std::hint::black_box(model.score_sparse(idx, val));
                1
            });
            report.record("score_sparse dense d=22 k=4", &s);
            let mut ik = 0usize;
            let s = bench_summary("kernel score dense d=22 k=4 (per example)", samples, || {
                let (idx, val) = ds.rows.row(ik % n);
                ik += 1;
                std::hint::black_box(kern.score(idx, val, &mut scratch));
                1
            });
            report.record("kernel_score dense d=22 k=4", &s);
            println!(
                "  fused vs scalar (dense): {}",
                ratio_str(
                    report.get("kernel_score dense d=22 k=4").unwrap(),
                    report.get("score_sparse dense d=22 k=4").unwrap()
                )
            );
            // Forced backends (the entry above is whatever `backend()`
            // dispatched to; these pin the label to the implementation).
            for b in [KernelBackend::Lanes, KernelBackend::Avx2] {
                let name = format!("kernel_score dense d=22 k=4 {}", b.name());
                if !b.available() {
                    println!("  {name}: backend unavailable on this host");
                    report.record_value(&name, f64::NAN);
                    continue;
                }
                let mut ib = 0usize;
                let s = bench_summary(
                    &format!("kernel score dense d=22 k=4 {} (per example)", b.name()),
                    samples,
                    || {
                        let (idx, val) = ds.rows.row(ib % n);
                        ib += 1;
                        std::hint::black_box(kern.score_backend(b, idx, val, &mut scratch));
                        1
                    },
                );
                report.record(&name, &s);
            }

            let smodel = FmModel::init(sparse.d(), 16, 0.05, &mut rng);
            let skern = FmKernel::from_model(&smodel);
            let mut sscratch = Scratch::for_k(16);
            let sn = sparse.n();
            let avg_nnz = sparse.nnz() as f64 / sn as f64;
            let mut si = 0usize;
            let s = bench_summary(
                &format!("score_sparse sparse nnz~{avg_nnz:.0} k=16 (per example)"),
                samples,
                || {
                    let (idx, val) = sparse.rows.row(si % sn);
                    si += 1;
                    std::hint::black_box(smodel.score_sparse(idx, val));
                    1
                },
            );
            report.record("score_sparse sparse k=16", &s);
            let mut ski = 0usize;
            let s = bench_summary(
                &format!("kernel score sparse nnz~{avg_nnz:.0} k=16 (per example)"),
                samples,
                || {
                    let (idx, val) = sparse.rows.row(ski % sn);
                    ski += 1;
                    std::hint::black_box(skern.score(idx, val, &mut sscratch));
                    1
                },
            );
            report.record("kernel_score sparse k=16", &s);
            println!(
                "  fused vs scalar (sparse): {}",
                ratio_str(
                    report.get("kernel_score sparse k=16").unwrap(),
                    report.get("score_sparse sparse k=16").unwrap()
                )
            );
            Ok(())
        },
    );

    section("per-example update (eqs. 11-13): scalar reference vs fused");
    guard(
        &mut report,
        &names(&["sgd_update_example d=22 k=4", "kernel_score_grad_step d=22 k=4"]),
        |report| {
            let mut m2 = model.clone();
            let mut a = vec![0f32; 4];
            let mut s2 = vec![0f32; 4];
            let mut j = 0usize;
            let s = bench_summary("sgd_update_example d=22 k=4 (per example)", samples, || {
                let r = j % n;
                j += 1;
                let (idx, val) = ds.rows.row(r);
                std::hint::black_box(sgd_update_example(
                    &mut m2,
                    idx,
                    val,
                    ds.labels[r],
                    ds.task,
                    1e-4,
                    1e-4,
                    1e-4,
                    &mut a,
                    &mut s2,
                ));
                1
            });
            report.record("sgd_update_example d=22 k=4", &s);
            let mut k2 = FmKernel::from_model(&model);
            let mut jk = 0usize;
            let s = bench_summary(
                "kernel score_grad_step d=22 k=4 (per example)",
                samples,
                || {
                    let r = jk % n;
                    jk += 1;
                    let (idx, val) = ds.rows.row(r);
                    std::hint::black_box(k2.score_grad_step(
                        idx,
                        val,
                        ds.labels[r],
                        ds.task,
                        1e-4,
                        1e-4,
                        1e-4,
                        &mut scratch,
                    ));
                    1
                },
            );
            report.record("kernel_score_grad_step d=22 k=4", &s);
            println!(
                "  fused vs scalar (update): {}",
                ratio_str(
                    report.get("kernel_score_grad_step d=22 k=4").unwrap(),
                    report.get("sgd_update_example d=22 k=4").unwrap()
                )
            );
            Ok(())
        },
    );

    section("engine column visits (Alg. 1 hot path): scalar vs lanes vs avx2");
    // Column-major twin of the sparse workload above: the engine's unit of
    // work is one parameter column applied to a worker's CSC column. The
    // lanes/avx2 entries force their backend explicitly so the labels stay
    // honest regardless of what `backend()` dispatched to.
    let vk = 16usize;
    guard(
        &mut report,
        &[
            format!("engine_visit_update scalar k={vk}"),
            format!("engine_visit_update lanes k={vk}"),
            format!("engine_visit_update avx2 k={vk}"),
            format!("engine_visit_recompute scalar k={vk}"),
            format!("engine_visit_recompute lanes k={vk}"),
            format!("engine_visit_finalize scalar k={vk}"),
            format!("engine_visit_finalize lanes k={vk}"),
        ],
        |report| {
            let vkp = padded_k(vk);
            let csc = sparse.rows.to_csc();
            let ncols_data = sparse.d();
            let nloc = sparse.n();
            let mut vrng = Pcg64::seeded(21);
            let vg: Vec<f32> = (0..nloc).map(|_| vrng.normal32(0.0, 1.0)).collect();
            let aa_s: Vec<f32> = (0..nloc * vk).map(|_| vrng.normal32(0.0, 0.5)).collect();
            let aa_l = pad_rows(&aa_s, nloc, vk, vkp);
            let w_cols: Vec<f32> = (0..ncols_data).map(|_| vrng.normal32(0.0, 0.3)).collect();
            let v_cols: Vec<f32> =
                (0..ncols_data * vk).map(|_| vrng.normal32(0.0, 0.3)).collect();
            let v_cols_l = pad_rows(&v_cols, ncols_data, vk, vkp);
            let h = VisitHyper {
                eta: 0.05,
                inv_n: 1.0 / nloc as f32,
                lambda_w: 1e-4,
                lambda_v: 1e-4,
                reg_split: 1.0,
            };

            // Update visit. All sides reset the column from the pristine
            // copy each call (same copy cost each side) so values stay
            // bounded.
            let mut wcol = 0f32;
            let mut vcol_s = vec![0f32; vk];
            let mut gv = vec![0f32; vk];
            let mut ci = 0usize;
            let s = bench_summary(
                &format!("engine_visit update scalar k={vk} (per column)"),
                samples,
                || {
                    let j = ci % ncols_data;
                    ci += 1;
                    let (rows, xs) = csc.col(j);
                    wcol = w_cols[j];
                    vcol_s.copy_from_slice(&v_cols[j * vk..(j + 1) * vk]);
                    visit::scalar::col_update(
                        rows, xs, &vg, &aa_s, vk, &mut wcol, &mut vcol_s, h, &mut gv,
                    );
                    std::hint::black_box(wcol);
                    1
                },
            );
            report.record(&format!("engine_visit_update scalar k={vk}"), &s);
            let mut vcol_l = vec![0f32; vkp];
            let mut vscratch = Scratch::for_k(vk);
            let mut cj = 0usize;
            let s = bench_summary(
                &format!("engine_visit update lanes k={vk} (per column)"),
                samples,
                || {
                    let j = cj % ncols_data;
                    cj += 1;
                    let (rows, xs) = csc.col(j);
                    wcol = w_cols[j];
                    vcol_l.copy_from_slice(&v_cols_l[j * vkp..(j + 1) * vkp]);
                    visit::col_update_backend(
                        KernelBackend::Lanes,
                        rows,
                        xs,
                        &vg,
                        &aa_l,
                        vkp,
                        &mut wcol,
                        &mut vcol_l,
                        h,
                        &mut vscratch,
                    );
                    std::hint::black_box(wcol);
                    1
                },
            );
            report.record(&format!("engine_visit_update lanes k={vk}"), &s);
            println!(
                "  lanes vs scalar (update visit): {}",
                ratio_str(
                    report.get(&format!("engine_visit_update lanes k={vk}")).unwrap(),
                    report.get(&format!("engine_visit_update scalar k={vk}")).unwrap()
                )
            );
            let avx2_name = format!("engine_visit_update avx2 k={vk}");
            if KernelBackend::Avx2.available() {
                let mut ca = 0usize;
                let s = bench_summary(
                    &format!("engine_visit update avx2 k={vk} (per column)"),
                    samples,
                    || {
                        let j = ca % ncols_data;
                        ca += 1;
                        let (rows, xs) = csc.col(j);
                        wcol = w_cols[j];
                        vcol_l.copy_from_slice(&v_cols_l[j * vkp..(j + 1) * vkp]);
                        visit::col_update_backend(
                            KernelBackend::Avx2,
                            rows,
                            xs,
                            &vg,
                            &aa_l,
                            vkp,
                            &mut wcol,
                            &mut vcol_l,
                            h,
                            &mut vscratch,
                        );
                        std::hint::black_box(wcol);
                        1
                    },
                );
                report.record(&avx2_name, &s);
                println!(
                    "  avx2 vs lanes (update visit): {}",
                    ratio_str(
                        report.get(&avx2_name).unwrap(),
                        report.get(&format!("engine_visit_update lanes k={vk}")).unwrap()
                    )
                );
            } else {
                println!("  {avx2_name}: backend unavailable on this host");
                report.record_value(&avx2_name, f64::NAN);
            }

            // Recompute visit (fold into the G/A partial sums).
            let mut xw_s = vec![0f32; nloc];
            let mut acc_a_s = vec![0f32; nloc * vk];
            let mut acc_s2_s = vec![0f32; nloc * vk];
            let mut ri = 0usize;
            let s = bench_summary(
                &format!("engine_visit recompute scalar k={vk} (per column)"),
                samples,
                || {
                    let j = ri % ncols_data;
                    ri += 1;
                    let (rows, xs) = csc.col(j);
                    visit::scalar::col_recompute(
                        rows,
                        xs,
                        w_cols[j],
                        &v_cols[j * vk..(j + 1) * vk],
                        vk,
                        &mut xw_s,
                        &mut acc_a_s,
                        &mut acc_s2_s,
                    );
                    1
                },
            );
            report.record(&format!("engine_visit_recompute scalar k={vk}"), &s);
            let mut xw_l = vec![0f32; nloc];
            let mut acc_a_l = vec![0f32; nloc * vkp];
            let mut acc_s2_l = vec![0f32; nloc * vkp];
            let mut rj = 0usize;
            let s = bench_summary(
                &format!("engine_visit recompute lanes k={vk} (per column)"),
                samples,
                || {
                    let j = rj % ncols_data;
                    rj += 1;
                    let (rows, xs) = csc.col(j);
                    visit::col_recompute_backend(
                        KernelBackend::Lanes,
                        rows,
                        xs,
                        w_cols[j],
                        &v_cols_l[j * vkp..(j + 1) * vkp],
                        vkp,
                        &mut xw_l,
                        &mut acc_a_l,
                        &mut acc_s2_l,
                    );
                    1
                },
            );
            report.record(&format!("engine_visit_recompute lanes k={vk}"), &s);
            println!(
                "  lanes vs scalar (recompute visit): {}",
                ratio_str(
                    report.get(&format!("engine_visit_recompute lanes k={vk}")).unwrap(),
                    report.get(&format!("engine_visit_recompute scalar k={vk}")).unwrap()
                )
            );

            // Finalize (pairwise reduction + loss multiplier per local row).
            let mut gbuf = vec![0f32; nloc];
            let s = bench_summary(
                &format!("engine_visit finalize scalar k={vk} (per row)"),
                samples,
                || {
                    std::hint::black_box(visit::scalar::finalize_rows(
                        0.1,
                        &xw_s,
                        &acc_a_s,
                        &acc_s2_s,
                        vk,
                        &sparse.labels,
                        sparse.task,
                        &mut gbuf,
                    ));
                    nloc as u64
                },
            );
            report.record(&format!("engine_visit_finalize scalar k={vk}"), &s);
            let s = bench_summary(
                &format!("engine_visit finalize lanes k={vk} (per row)"),
                samples,
                || {
                    std::hint::black_box(visit::finalize_rows_backend(
                        KernelBackend::Lanes,
                        0.1,
                        &xw_l,
                        &acc_a_l,
                        &acc_s2_l,
                        vkp,
                        &sparse.labels,
                        sparse.task,
                        &mut gbuf,
                    ));
                    nloc as u64
                },
            );
            report.record(&format!("engine_visit_finalize lanes k={vk}"), &s);
            println!(
                "  lanes vs scalar (finalize): {}",
                ratio_str(
                    report.get(&format!("engine_visit_finalize lanes k={vk}")).unwrap(),
                    report.get(&format!("engine_visit_finalize scalar k={vk}")).unwrap()
                )
            );
            Ok(())
        },
    );

    section("token codec (wire format)");
    guard(
        &mut report,
        &names(&[
            "encode_token k=16",
            "decode_token k=16",
            "wire bytes_per_iter f32",
            "wire bytes_per_iter bf16",
        ]),
        |report| {
            let tok = Token {
                j: 123,
                iter: 5,
                phase: Phase::Update,
                visits: 2,
                w: Box::from([0.5f32]),
                v: (0..16).map(|x| x as f32).collect(),
            };
            let mut buf = Vec::new();
            let s = bench_summary("encode_token k=16", samples, || {
                codec::encode_token(&tok, &mut buf);
                std::hint::black_box(buf.len());
                1
            });
            report.record("encode_token k=16", &s);
            codec::encode_token(&tok, &mut buf);
            let s = bench_summary("decode_token k=16", samples, || {
                std::hint::black_box(codec::decode_token(&buf).unwrap());
                1
            });
            report.record("decode_token k=16", &s);

            // Ring bytes for the full realsim-scale token set (d=20958,
            // k=16, c=40 — the shape the cluster e2e runs) crossing one
            // hop, per wire precision. Each token pays its payload frame
            // plus the 4-byte length prefix and the unauthenticated
            // envelope; bf16 halves only the payload half, so the ratio
            // lands just above 0.5 (EXPERIMENTS.md documents the
            // <= 0.55x bar).
            let (dw, kw, cw) = (20_958usize, 16usize, 40usize);
            let kpw = padded_k(kw);
            let nblocks = dw.div_ceil(cw);
            let env = codec::envelope_overhead(false);
            let (mut bytes_f32, mut bytes_bf16) = (0usize, 0usize);
            for b in 0..=nblocks {
                let t = if b == nblocks {
                    Token {
                        j: BIAS,
                        iter: 0,
                        phase: Phase::Update,
                        visits: 0,
                        w: Box::from([0.1f32]),
                        v: Vec::new().into_boxed_slice(),
                    }
                } else {
                    let ncols = cw.min(dw - b * cw);
                    Token {
                        j: b as u32,
                        iter: 0,
                        phase: Phase::Update,
                        visits: 0,
                        w: vec![0.1f32; ncols].into_boxed_slice(),
                        v: vec![0.1f32; ncols * kpw].into_boxed_slice(),
                    }
                };
                bytes_f32 += codec::padded_token_wire_size(&t, kw) + 4 + env;
                bytes_bf16 += codec::token_wire_size_bf16(&t, kw) + 4 + env;
            }
            println!(
                "  wire bytes per token-set hop (d={dw} k={kw} c={cw}): \
                 f32 {bytes_f32} B, bf16 {bytes_bf16} B ({:.3}x)",
                bytes_bf16 as f64 / bytes_f32 as f64
            );
            report.record_value("wire bytes_per_iter f32", bytes_f32 as f64);
            report.record_value("wire bytes_per_iter bf16", bytes_bf16 as f64);
            Ok(())
        },
    );

    section("transport (token hops)");
    guard(
        &mut report,
        &names(&["local transport send+recv"]),
        |report| {
            let t = LocalTransport::new(2);
            let mk = || Token {
                j: 1,
                iter: 0,
                phase: Phase::Update,
                visits: 0,
                w: Box::from([0f32]),
                v: vec![0f32; 16].into_boxed_slice(),
            };
            let mut tok_cycle = Some(mk());
            let s = bench_summary("local transport send+recv (per hop)", samples, || {
                let tk = tok_cycle.take().unwrap();
                t.send(0, tk);
                tok_cycle = Some(
                    t.recv_timeout(0, std::time::Duration::from_millis(100))
                        .unwrap(),
                );
                1
            });
            report.record("local transport send+recv", &s);
            Ok(())
        },
    );

    section("engine end-to-end (ijcnn1 twin, P=4, 2 iters)");
    guard(
        &mut report,
        &names(&[
            "engine ns_per_hop (ijcnn1 P=4)",
            "engine ns_per_coord (ijcnn1 P=4)",
        ]),
        |report| {
            let cfg = dsfacto::config::ExperimentConfig {
                dataset: dsfacto::config::DatasetSpec::Table2("ijcnn1".into()),
                trainer: dsfacto::config::TrainerKind::Nomad,
                fm: dsfacto::fm::FmHyper {
                    k: 4,
                    ..Default::default()
                },
                workers: 4,
                outer_iters: 2,
                eval_every: usize::MAX,
                ..Default::default()
            };
            let trainer = cfg.trainer.build(&cfg);
            let sw = dsfacto::util::timer::Stopwatch::start();
            trainer.fit(&ds, None, &mut ())?;
            let secs = sw.secs();
            let stats = trainer.stats().expect("engine counters");
            let ns_per_hop = secs * 1e9 / stats.messages.max(1) as f64;
            let ns_per_coord =
                stats.total_busy_secs() * 1e9 / stats.coordinate_updates.max(1) as f64;
            println!(
                "engine: {} hops in {:.3}s = {:.0} ns/hop; {} coord updates = {:.0} ns/coord; busy makespan {:.3}s",
                stats.messages,
                secs,
                ns_per_hop,
                stats.coordinate_updates,
                ns_per_coord,
                stats.makespan_secs(),
            );
            report.record_value("engine ns_per_hop (ijcnn1 P=4)", ns_per_hop);
            report.record_value("engine ns_per_coord (ijcnn1 P=4)", ns_per_coord);
            Ok(())
        },
    );

    section("partition plans: contiguous vs nnz-balanced (realsim twin, P=8, 2 iters)");
    // Same Zipf-skewed realsim twin as the sparse-scoring section above.
    // Derived values (EXPERIMENTS.md §Partitioning): makespan is seconds,
    // imbalance is the max/mean shard-nnz ratio — both land in the JSON's
    // value slot like the other derived entries.
    guard(
        &mut report,
        &names(&[
            "engine makespan_secs contiguous (realsim-2k P=8)",
            "partition imbalance contiguous (realsim-2k P=8)",
            "engine makespan_secs balanced (realsim-2k P=8)",
            "partition imbalance balanced (realsim-2k P=8)",
        ]),
        |report| {
            for plan in ["contiguous", "balanced"] {
                let mut cfg = dsfacto::config::ExperimentConfig {
                    trainer: dsfacto::config::TrainerKind::Nomad,
                    fm: dsfacto::fm::FmHyper {
                        k: 16,
                        init_std: 0.05,
                        ..Default::default()
                    },
                    workers: 8,
                    outer_iters: 2,
                    eta: dsfacto::optim::LrSchedule::Constant(0.5),
                    eval_every: usize::MAX,
                    ..Default::default()
                };
                cfg.set("row_partition", plan)?;
                let trainer = cfg.trainer.build(&cfg);
                trainer.fit(&sparse, None, &mut ())?;
                let stats = trainer.stats().expect("engine counters");
                let ps = &stats.partition;
                let mk = stats.makespan_secs();
                println!(
                    "  {plan:>12}: busy makespan {:.3}s, shard imbalance {:.3} (shard nnz {}..{})",
                    mk,
                    ps.imbalance,
                    ps.shard_nnz.iter().min().copied().unwrap_or(0),
                    ps.shard_nnz.iter().max().copied().unwrap_or(0),
                );
                report.record_value(&format!("engine makespan_secs {plan} (realsim-2k P=8)"), mk);
                report.record_value(
                    &format!("partition imbalance {plan} (realsim-2k P=8)"),
                    ps.imbalance,
                );
            }
            Ok(())
        },
    );

    section("out-of-core data layer: ingest throughput + resident shard bytes");
    // Same Zipf-skewed realsim twin, written once as LIBSVM text; the two
    // ingest paths read identical bytes. `memory` = the full in-RAM parse
    // (libsvm::parse), `stream` = the bounded-memory shard-cache ingester
    // (EXPERIMENTS.md §Data). Derived values: rows/sec in the value slot.
    let tmp = std::env::temp_dir().join("dsfacto_bench_ingest");
    let cache_dir = tmp.join("cache");
    // (parsed dataset, full resident bytes) — handed to the prefetch
    // section below, which records nulls if this section failed.
    let mut ingested: Option<(dsfacto::data::Dataset, usize)> = None;
    guard(
        &mut report,
        &names(&[
            "ingest rows_per_sec memory (realsim-2k)",
            "ingest rows_per_sec stream (realsim-2k P=8)",
            "resident shard_bytes full (realsim-2k)",
            "resident shard_bytes cache (realsim-2k P=8)",
        ]),
        |report| {
            std::fs::create_dir_all(&tmp)?;
            let svm_path = tmp.join("realsim-2k.svm");
            dsfacto::data::libsvm::save(&sparse, &svm_path)?;
            let text = std::fs::read_to_string(&svm_path)?;
            let sw = dsfacto::util::timer::Stopwatch::start();
            let parsed = dsfacto::data::libsvm::parse(
                &text,
                "realsim-2k",
                sparse.task,
                Some(sparse.d()),
            )?;
            let mem_secs = sw.secs();
            let mem_rows_per_sec = parsed.n() as f64 / mem_secs.max(1e-9);
            drop(text);
            std::fs::remove_dir_all(&cache_dir).ok();
            let ingest_opts = dsfacto::data::libsvm::IngestOptions {
                task: sparse.task,
                n_features: Some(sparse.d()),
                strategy: dsfacto::partition::RowStrategy::Contiguous,
                shards: 8,
                chunk_rows: 512,
            };
            let sw = dsfacto::util::timer::Stopwatch::start();
            let ingest = dsfacto::data::libsvm::stream_ingest(
                &svm_path,
                "realsim-2k",
                &ingest_opts,
                &cache_dir,
            )?;
            let stream_secs = sw.secs();
            let stream_rows_per_sec = ingest.n as f64 / stream_secs.max(1e-9);
            // Resident bytes: the full CSR + labels every trainer used to
            // hold, vs the largest transient the cache path ever holds
            // (one shard).
            let full_bytes = 8 * (parsed.n() + 1) + 8 * parsed.nnz() + 4 * parsed.n();
            println!(
                "  ingest: memory {mem_rows_per_sec:.0} rows/s, stream {stream_rows_per_sec:.0} rows/s \
                 ({} chunks); resident full {full_bytes} B vs cache peak {} B ({:.1}x smaller)",
                ingest.chunks_flushed,
                ingest.peak_resident_bytes,
                full_bytes as f64 / ingest.peak_resident_bytes.max(1) as f64,
            );
            report.record_value("ingest rows_per_sec memory (realsim-2k)", mem_rows_per_sec);
            report.record_value(
                "ingest rows_per_sec stream (realsim-2k P=8)",
                stream_rows_per_sec,
            );
            report.record_value("resident shard_bytes full (realsim-2k)", full_bytes as f64);
            report.record_value(
                "resident shard_bytes cache (realsim-2k P=8)",
                ingest.peak_resident_bytes as f64,
            );
            ingested = Some((parsed, full_bytes));
            Ok(())
        },
    );

    section("shard prefetch: synchronous vs double-buffered sweeps (realsim-2k P=8)");
    // One "epoch" = one full streaming_objective fold over the 8 cached
    // shards (the coordinator's trace/eval access pattern). `sync` loads
    // each shard on demand; `prefetch` is the same source behind the
    // coordinator's double-buffered PrefetchSource decorator, which
    // overlaps the next shard's disk read with the current fold.
    guard(
        &mut report,
        &names(&[
            "prefetch epoch_secs sync (realsim-2k P=8)",
            "prefetch epoch_secs prefetch (realsim-2k P=8)",
            "resident coordinator_bytes full (realsim-2k)",
            "resident coordinator_bytes stream (realsim-2k P=8)",
        ]),
        |report| {
            use dsfacto::data::{DataSource, PrefetchSource, ShardCacheSource};
            let Some((parsed, full_bytes)) = ingested.as_ref() else {
                anyhow::bail!("ingest section did not complete");
            };
            let full_bytes = *full_bytes;
            let epochs = 4usize;
            let pmodel = FmModel::init(parsed.d(), 8, 0.05, &mut rng);
            let sync_src = ShardCacheSource::open(&cache_dir)?;
            let plan = sync_src.plan(dsfacto::partition::RowStrategy::Contiguous, 8)?;
            let sw = dsfacto::util::timer::Stopwatch::start();
            for _ in 0..epochs {
                std::hint::black_box(dsfacto::train::streaming_objective(
                    &sync_src, &plan, &pmodel, 1e-4, 1e-4,
                )?);
            }
            let sync_epoch = sw.secs() / epochs as f64;
            let pf_src =
                PrefetchSource::new(std::sync::Arc::new(ShardCacheSource::open(&cache_dir)?));
            let sw = dsfacto::util::timer::Stopwatch::start();
            for _ in 0..epochs {
                std::hint::black_box(dsfacto::train::streaming_objective(
                    &pf_src, &plan, &pmodel, 1e-4, 1e-4,
                )?);
            }
            let pf_epoch = sw.secs() / epochs as f64;
            println!(
                "  sync {:.2} ms/epoch vs prefetch {:.2} ms/epoch ({} hits / {} misses); \
                 coordinator resident: full {full_bytes} B vs stream peak {} B ({} shards)",
                sync_epoch * 1e3,
                pf_epoch * 1e3,
                pf_src.prefetch_hits(),
                pf_src.prefetch_misses(),
                pf_src.peak_resident_bytes(),
                pf_src.peak_resident_shards(),
            );
            report.record_value("prefetch epoch_secs sync (realsim-2k P=8)", sync_epoch);
            report.record_value("prefetch epoch_secs prefetch (realsim-2k P=8)", pf_epoch);
            report.record_value(
                "resident coordinator_bytes full (realsim-2k)",
                full_bytes as f64,
            );
            report.record_value(
                "resident coordinator_bytes stream (realsim-2k P=8)",
                pf_src.peak_resident_bytes() as f64,
            );
            Ok(())
        },
    );
    std::fs::remove_dir_all(&tmp).ok();

    section("cluster: per-epoch wall clock, in-process vs multi-process (housing, P=2, 3 iters)");
    // Same experiment twice: once as threads in this process, once as a
    // real `dsfacto driver` + 2 `dsfacto worker` subprocess ring over the
    // same shard cache. The gap is the cross-process tax (TCP hops,
    // control-plane epochs, process startup amortized over 3 iterations).
    let ctmp = std::env::temp_dir().join("dsfacto_bench_cluster");
    guard(
        &mut report,
        &names(&[
            "cluster epoch_secs inprocess (housing P=2)",
            "cluster epoch_secs multiprocess (housing P=2)",
            "cluster recovery_secs clean (housing P=2)",
            "cluster recovery_secs faulted (housing P=2)",
        ]),
        |report| {
            std::fs::remove_dir_all(&ctmp).ok();
            std::fs::create_dir_all(&ctmp)?;
            let cds = synth::table2_dataset("housing", 5)?;
            let ccache = ctmp.join("cache");
            dsfacto::data::cache::write_cache(
                &cds,
                dsfacto::partition::RowStrategy::Contiguous,
                2,
                &ccache,
            )?;
            let citers = 3usize;
            let mut ccfg = dsfacto::config::ExperimentConfig {
                trainer: dsfacto::config::TrainerKind::Nomad,
                workers: 2,
                outer_iters: citers,
                eta: dsfacto::optim::LrSchedule::Constant(0.5),
                eval_every: usize::MAX,
                ..Default::default()
            };
            ccfg.set("dataset", &format!("cache:{}", ccache.display()))?;
            ccfg.set("data_cache", &ccache.display().to_string())?;
            ccfg.set("cols_per_token", "5")?;
            let ctrainer = ccfg.trainer.build(&ccfg);
            let sw = dsfacto::util::timer::Stopwatch::start();
            ctrainer.fit(&cds, None, &mut ())?;
            let inproc_epoch = sw.secs() / citers as f64;
            println!("  in-process:    {:.1} ms/epoch", inproc_epoch * 1e3);
            report.record_value("cluster epoch_secs inprocess (housing P=2)", inproc_epoch);
            match cluster_driver_secs(&ccache, citers) {
                Ok(total) => {
                    let mp_epoch = total / citers as f64;
                    println!(
                        "  multi-process: {:.1} ms/epoch ({:.1}x in-process)",
                        mp_epoch * 1e3,
                        mp_epoch / inproc_epoch.max(1e-12)
                    );
                    report.record_value("cluster epoch_secs multiprocess (housing P=2)", mp_epoch);
                    // Recovery tax: the same schedule with one worker scripted to
                    // die mid-epoch (`DSFACTO_CHAOS=kill:2`) and a replacement
                    // joining after the driver's restart marker — detect + abort +
                    // re-join + checkpoint restart, vs the clean run above.
                    report.record_value("cluster recovery_secs clean (housing P=2)", total);
                    match cluster_faulted_secs(&ccache, citers, &ctmp.join("chaos_ckpt")) {
                        Ok(faulted) => {
                            println!(
                                "  faulted:       {:.0} ms total ({:.1}x clean; scripted kill + restart)",
                                faulted * 1e3,
                                faulted / total.max(1e-12)
                            );
                            report.record_value(
                                "cluster recovery_secs faulted (housing P=2)",
                                faulted,
                            );
                        }
                        Err(e) => eprintln!("  skipping the faulted cluster bench: {e:#}"),
                    }
                }
                // Sandboxed environments without loopback sockets still get the
                // rest of the report (the guard writes nulls for the skipped
                // entries).
                Err(e) => eprintln!("  skipping the multi-process cluster bench: {e:#}"),
            }
            Ok(())
        },
    );
    std::fs::remove_dir_all(&ctmp).ok();

    report.write(&json_path)?;
    println!("\nwrote {json_path} ({} entries)", report.entries.len());
    Ok(())
}

/// Runs one driver + 2 worker subprocess ring over `cache` and returns
/// the driver's wall time from worker launch to exit.
fn cluster_driver_secs(cache: &std::path::Path, iters: usize) -> anyhow::Result<f64> {
    use std::io::BufRead;
    use std::process::{Command, Stdio};
    use std::time::{Duration, Instant};

    let bin = env!("CARGO_BIN_EXE_dsfacto");
    let dataset = format!("cache:{}", cache.display());
    let mut driver = Command::new(bin)
        .args([
            "driver",
            "--dataset",
            &dataset,
            "--workers",
            "2",
            "--outer-iters",
            &iters.to_string(),
            "--eta",
            "constant:0.5",
            "--seed",
            "5",
            "--cols-per-token",
            "5",
            "--train-frac",
            "1",
            "--addr",
            "127.0.0.1:0",
            "--quiet",
        ])
        .stdin(Stdio::null())
        .stdout(Stdio::piped())
        .spawn()?;
    let stdout = driver.stdout.take().expect("driver stdout piped");
    let mut reader = std::io::BufReader::new(stdout);
    let mut addr = None;
    let mut line = String::new();
    while reader.read_line(&mut line)? > 0 {
        if let Some(rest) = line.split("control on ").nth(1) {
            addr = Some(rest.trim().to_string());
            break;
        }
        line.clear();
    }
    let Some(addr) = addr else {
        let _ = driver.kill();
        let _ = driver.wait();
        anyhow::bail!("driver never printed its control address");
    };

    let sw = Instant::now();
    let mut workers = Vec::new();
    for _ in 0..2 {
        match Command::new(bin)
            .args(["worker", "--driver", &addr])
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .spawn()
        {
            Ok(w) => workers.push(w),
            Err(e) => {
                let _ = driver.kill();
                for mut w in workers {
                    let _ = w.kill();
                }
                return Err(e.into());
            }
        }
    }
    // Keep draining the pipe so the driver's final summary can't block it.
    let drain = std::thread::spawn(move || {
        let mut sink = String::new();
        while matches!(reader.read_line(&mut sink), Ok(n) if n > 0) {
            sink.clear();
        }
    });
    let deadline = Instant::now() + Duration::from_secs(180);
    let ok = loop {
        match driver.try_wait()? {
            Some(status) => break status.success(),
            None if Instant::now() >= deadline => {
                let _ = driver.kill();
                break false;
            }
            None => std::thread::sleep(Duration::from_millis(50)),
        }
    };
    let secs = sw.elapsed().as_secs_f64();
    let _ = drain.join();
    for mut w in workers {
        let _ = w.kill();
        let _ = w.wait();
    }
    anyhow::ensure!(ok, "cluster driver exited unsuccessfully");
    Ok(secs)
}

/// The same subprocess ring under a scripted fault: worker-b runs with
/// `DSFACTO_CHAOS=kill:2` (exit mid-epoch, before reporting), and a
/// replacement worker is launched once the driver prints its restart
/// marker. Returns the wall time from worker launch to driver exit —
/// the full death-detect + abort + re-join + checkpoint-restart cost on
/// the same schedule `cluster_driver_secs` times cleanly.
fn cluster_faulted_secs(
    cache: &std::path::Path,
    iters: usize,
    ckpt: &std::path::Path,
) -> anyhow::Result<f64> {
    use std::io::BufRead;
    use std::process::{Command, Stdio};
    use std::time::{Duration, Instant};

    std::fs::create_dir_all(ckpt)?;
    let bin = env!("CARGO_BIN_EXE_dsfacto");
    let dataset = format!("cache:{}", cache.display());
    let ckpt_s = ckpt.display().to_string();
    // Not --quiet: the restart marker on stdout is what cues the
    // replacement worker.
    let mut driver = Command::new(bin)
        .args([
            "driver",
            "--dataset",
            &dataset,
            "--workers",
            "2",
            "--outer-iters",
            &iters.to_string(),
            "--eta",
            "constant:0.5",
            "--seed",
            "5",
            "--cols-per-token",
            "5",
            "--train-frac",
            "1",
            "--addr",
            "127.0.0.1:0",
            "--ckpt-dir",
            &ckpt_s,
            "--ckpt-every",
            "1",
            "--heartbeat-timeout",
            "2",
            "--max-restarts",
            "2",
        ])
        .stdin(Stdio::null())
        .stdout(Stdio::piped())
        .spawn()?;
    let stdout = driver.stdout.take().expect("driver stdout piped");
    let mut reader = std::io::BufReader::new(stdout);
    let mut addr = None;
    let mut line = String::new();
    while reader.read_line(&mut line)? > 0 {
        if let Some(rest) = line.split("control on ").nth(1) {
            addr = Some(rest.trim().to_string());
            break;
        }
        line.clear();
    }
    let Some(addr) = addr else {
        let _ = driver.kill();
        let _ = driver.wait();
        anyhow::bail!("driver never printed its control address");
    };

    let worker_args = [
        "worker",
        "--driver",
        addr.as_str(),
        "--ckpt-dir",
        ckpt_s.as_str(),
        "--ckpt-every",
        "1",
    ];
    let spawn_worker = |chaos: Option<&str>| {
        let mut cmd = Command::new(bin);
        cmd.args(worker_args).stdin(Stdio::null()).stdout(Stdio::null());
        if let Some(spec) = chaos {
            cmd.env("DSFACTO_CHAOS", spec);
        }
        cmd.spawn()
    };
    let sw = Instant::now();
    let mut workers = Vec::new();
    for chaos in [None, Some("kill:2")] {
        match spawn_worker(chaos) {
            Ok(w) => workers.push(w),
            Err(e) => {
                let _ = driver.kill();
                for mut w in workers {
                    let _ = w.kill();
                }
                return Err(e.into());
            }
        }
    }
    // Drain the pipe (so the driver never blocks on it) while watching
    // for the generation-restart marker.
    let (restart_tx, restart_rx) = std::sync::mpsc::channel::<()>();
    let drain = std::thread::spawn(move || {
        let mut sink = String::new();
        while matches!(reader.read_line(&mut sink), Ok(n) if n > 0) {
            if sink.contains("restarting from iteration") {
                let _ = restart_tx.send(());
            }
            sink.clear();
        }
    });
    let deadline = Instant::now() + Duration::from_secs(180);
    let mut replaced = false;
    let ok = loop {
        if !replaced && restart_rx.try_recv().is_ok() {
            if let Ok(w) = spawn_worker(None) {
                workers.push(w);
            }
            replaced = true;
        }
        match driver.try_wait()? {
            Some(status) => break status.success(),
            None if Instant::now() >= deadline => {
                let _ = driver.kill();
                break false;
            }
            None => std::thread::sleep(Duration::from_millis(50)),
        }
    };
    let secs = sw.elapsed().as_secs_f64();
    let _ = drain.join();
    for mut w in workers {
        let _ = w.kill();
        let _ = w.wait();
    }
    anyhow::ensure!(ok, "faulted cluster driver exited unsuccessfully");
    anyhow::ensure!(replaced, "the scripted kill never triggered a restart");
    Ok(secs)
}
