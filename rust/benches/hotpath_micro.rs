//! Hot-path microbenchmarks (EXPERIMENTS.md §Perf): the building blocks
//! whose cost bounds every trainer — FM scoring, the per-example SGD
//! update, the engine's column visits, the token codec, and transports.
//!
//! Run: `cargo bench --bench hotpath_micro`.

use dsfacto::cluster::{codec, LocalTransport, Transport};
use dsfacto::data::synth;
use dsfacto::fm::FmModel;
use dsfacto::nomad::token::{Phase, Token};
use dsfacto::optim::sgd_update_example;
use dsfacto::util::bench::{bench_ns_per_op, section};
use dsfacto::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let mut rng = Pcg64::seeded(1);

    section("FM scoring (eq. 4 rewrite)");
    // Dense ijcnn1-like: D=22, K=4.
    let ds = synth::table2_dataset("ijcnn1", 7)?;
    let model = {
        let mut m = FmModel::init(ds.d(), 4, 0.1, &mut rng);
        for x in m.w.iter_mut() {
            *x = rng.normal32(0.0, 0.3);
        }
        m
    };
    let n = ds.n();
    let mut i = 0usize;
    bench_ns_per_op("score_sparse dense d=22 k=4 (per example)", 20, || {
        let (idx, val) = ds.rows.row(i % n);
        i += 1;
        std::hint::black_box(model.score_sparse(idx, val));
        1
    });

    // Sparse realsim-like row: ~52 nnz, K=16.
    let spec = synth::SynthSpec {
        n: 2000,
        ..synth::SynthSpec::table2("realsim")?
    };
    let sparse = synth::generate(&spec, 8).dataset;
    let smodel = FmModel::init(sparse.d(), 16, 0.05, &mut rng);
    let sn = sparse.n();
    let mut si = 0usize;
    let nnz_total: usize = sparse.nnz();
    let avg_nnz = nnz_total as f64 / sn as f64;
    bench_ns_per_op(
        &format!("score_sparse sparse nnz~{avg_nnz:.0} k=16 (per example)"),
        20,
        || {
            let (idx, val) = sparse.rows.row(si % sn);
            si += 1;
            std::hint::black_box(smodel.score_sparse(idx, val));
            1
        },
    );

    section("per-example SGD update (eqs. 11-13)");
    let mut m2 = model.clone();
    let mut a = vec![0f32; 4];
    let mut j = 0usize;
    bench_ns_per_op("sgd_update_example d=22 k=4 (per example)", 20, || {
        let (idx, val) = ds.rows.row(j % n);
        j += 1;
        std::hint::black_box(sgd_update_example(
            &mut m2,
            idx,
            val,
            ds.labels[j % n],
            ds.task,
            1e-4,
            1e-4,
            1e-4,
            &mut a,
        ));
        1
    });

    section("token codec (wire format)");
    let tok = Token {
        j: 123,
        iter: 5,
        phase: Phase::Update,
        visits: 2,
        w: Box::from([0.5f32]),
        v: (0..16).map(|x| x as f32).collect(),
    };
    let mut buf = Vec::new();
    bench_ns_per_op("encode_token k=16", 20, || {
        codec::encode_token(&tok, &mut buf);
        std::hint::black_box(buf.len());
        1
    });
    codec::encode_token(&tok, &mut buf);
    bench_ns_per_op("decode_token k=16", 20, || {
        std::hint::black_box(codec::decode_token(&buf).unwrap());
        1
    });

    section("transport (token hops)");
    let t = LocalTransport::new(2);
    let mk = || Token {
        j: 1,
        iter: 0,
        phase: Phase::Update,
        visits: 0,
        w: Box::from([0f32]),
        v: vec![0f32; 16].into_boxed_slice(),
    };
    let mut tok_cycle = Some(mk());
    bench_ns_per_op("local transport send+recv (per hop)", 20, || {
        let tk = tok_cycle.take().unwrap();
        t.send(0, tk);
        tok_cycle = Some(
            t.recv_timeout(0, std::time::Duration::from_millis(100))
                .unwrap(),
        );
        1
    });

    section("engine end-to-end (ijcnn1 twin, P=4, 2 iters)");
    let cfg = dsfacto::config::ExperimentConfig {
        dataset: dsfacto::config::DatasetSpec::Table2("ijcnn1".into()),
        trainer: dsfacto::config::TrainerKind::Nomad,
        fm: dsfacto::fm::FmHyper {
            k: 4,
            ..Default::default()
        },
        workers: 4,
        outer_iters: 2,
        eval_every: usize::MAX,
        ..Default::default()
    };
    let trainer = cfg.trainer.build(&cfg);
    let sw = dsfacto::util::timer::Stopwatch::start();
    trainer.fit(&ds, None, &mut ())?;
    let secs = sw.secs();
    let stats = trainer.stats().expect("engine counters");
    println!(
        "engine: {} hops in {:.3}s = {:.0} ns/hop; {} coord updates = {:.0} ns/coord; busy makespan {:.3}s",
        stats.messages,
        secs,
        secs * 1e9 / stats.messages as f64,
        stats.coordinate_updates,
        stats.total_busy_secs() * 1e9 / stats.coordinate_updates.max(1) as f64,
        stats.makespan_secs(),
    );
    Ok(())
}
