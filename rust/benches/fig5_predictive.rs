//! Figure 5 reproduction: predictive performance — test RMSE (regression)
//! and test accuracy (classification) of DS-FACTO vs libFM on the
//! diabetes, housing and ijcnn1 twins, as a function of iteration/time.
//!
//! Run: `cargo bench --bench fig5_predictive`.

use dsfacto::config::{DatasetSpec, ExperimentConfig, TrainerKind};
use dsfacto::data::{synth, Task};
use dsfacto::fm::FmHyper;
use dsfacto::metrics::TrainOutput;
use dsfacto::optim::LrSchedule;

struct Setup {
    dataset: &'static str,
    iters: usize,
    nomad_eta: f32,
    libfm_eta: f32,
    libfm_epochs: usize,
    eval_every: usize,
}

const SETUPS: &[Setup] = &[
    Setup {
        dataset: "diabetes",
        iters: 60,
        nomad_eta: 0.5,
        libfm_eta: 0.02,
        libfm_epochs: 40,
        eval_every: 5,
    },
    Setup {
        dataset: "housing",
        iters: 60,
        nomad_eta: 0.5,
        libfm_eta: 0.02,
        libfm_epochs: 40,
        eval_every: 5,
    },
    Setup {
        dataset: "ijcnn1",
        iters: 25,
        nomad_eta: 1.0,
        libfm_eta: 0.01,
        libfm_epochs: 8,
        eval_every: 5,
    },
];

fn metric_of(pt: &dsfacto::metrics::TracePoint, task: Task) -> Option<f64> {
    pt.test.map(|m| m.headline(task))
}

fn print_series(label: &str, out: &TrainOutput, task: Task) {
    let metric_name = match task {
        Task::Regression => "test RMSE",
        Task::Classification => "test accuracy",
    };
    println!("  {label} (iter, secs, {metric_name}):");
    for pt in &out.trace {
        if let Some(m) = metric_of(pt, task) {
            println!("    {:>4}  {:>9.3}  {:.5}", pt.iter, pt.secs, m);
        }
    }
}

fn final_metric(out: &TrainOutput, task: Task) -> f64 {
    out.trace
        .iter()
        .rev()
        .find_map(|p| metric_of(p, task))
        .unwrap_or(f64::NAN)
}

fn main() -> anyhow::Result<()> {
    println!("== Figure 5: predictive performance (test RMSE / accuracy) ==");
    let mut rows = Vec::new();
    for s in SETUPS {
        let ds = synth::table2_dataset(s.dataset, 42)?;
        let task = ds.task;
        let (train, test) = ds.split(0.8, 43);
        let fm = FmHyper {
            k: 4,
            ..Default::default()
        };
        println!("\n-- {} ({:?}) --", s.dataset, task);

        // Both engines run through the uniform Trainer API.
        let mk_cfg = |trainer, iters, eta, eval_every| ExperimentConfig {
            dataset: DatasetSpec::Table2(s.dataset.into()),
            trainer,
            fm,
            workers: 4,
            outer_iters: iters,
            eta: LrSchedule::Constant(eta),
            eval_every,
            ..Default::default()
        };
        let ncfg = mk_cfg(TrainerKind::Nomad, s.iters, s.nomad_eta, s.eval_every);
        let nomad = ncfg.trainer.build(&ncfg).fit(&train, Some(&test), &mut ())?;

        let lcfg = mk_cfg(TrainerKind::Libfm, s.libfm_epochs, s.libfm_eta, 1);
        let libfm = lcfg.trainer.build(&lcfg).fit(&train, Some(&test), &mut ())?;

        print_series("ds-facto (P=4)", &nomad, task);
        print_series("libfm (1 thread)", &libfm, task);
        rows.push((s.dataset, task, final_metric(&nomad, task), final_metric(&libfm, task)));
    }

    println!("\n== Figure 5 summary (final held-out metric) ==");
    println!(
        "{:<10} {:<14} {:>10} {:>10} {:>10}",
        "dataset", "metric", "ds-facto", "libfm", "delta"
    );
    let mut ok = true;
    for (name, task, n, l) in rows {
        let metric = match task {
            Task::Regression => "RMSE (lower+)",
            Task::Classification => "accuracy",
        };
        println!("{name:<10} {metric:<14} {n:>10.5} {l:>10.5} {:>+10.5}", n - l);
        ok &= match task {
            Task::Regression => n < l * 1.2 + 0.02,
            Task::Classification => n > l - 0.05,
        };
    }
    println!(
        "\npaper shape: DS-FACTO matches libFM's predictive performance — {}",
        if ok { "REPRODUCED" } else { "NOT reproduced" }
    );
    anyhow::ensure!(ok, "predictive parity failed");
    Ok(())
}
