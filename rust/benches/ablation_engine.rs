//! Ablation studies over DS-FACTO's design choices (DESIGN.md §6b):
//!
//! * token granularity (`cols_per_token`): single-column (paper-literal)
//!   vs auto-blocked circulation;
//! * update-visit semantics: mean-gradient vs stochastic sampling;
//! * incremental synchronization: DS-FACTO vs the bulk-sync counterpart
//!   (synchronous DSGD) vs full-barrier GD on the same budget.
//!
//! Every variant is just an `ExperimentConfig` — granularity, update mode
//! and the competing trainers are all config keys dispatched through
//! `TrainerKind::build`.
//!
//! Run: `cargo bench --bench ablation_engine`.

use dsfacto::config::{DatasetSpec, ExperimentConfig, TrainerKind};
use dsfacto::data::synth;
use dsfacto::fm::FmHyper;
use dsfacto::optim::LrSchedule;

fn main() -> anyhow::Result<()> {
    // ---------------------------------------------------------------
    println!("== Ablation 1: token granularity (realsim twin, P=8, 2 iters) ==");
    println!(
        "{:>14} {:>8} {:>10} {:>10} {:>12}",
        "cols/token", "tokens", "makespan", "speedup*", "msgs"
    );
    let ds = synth::table2_dataset("realsim", 42)?;
    let fm16 = FmHyper {
        k: 16,
        ..Default::default()
    };
    let mut baseline = None;
    for cols in [1usize, 8, 40, 256, 2048] {
        let cfg = ExperimentConfig {
            dataset: DatasetSpec::Table2("realsim".into()),
            trainer: TrainerKind::Nomad,
            fm: fm16,
            workers: 8,
            outer_iters: 2,
            eta: LrSchedule::Constant(0.5),
            eval_every: usize::MAX,
            cols_per_token: cols,
            ..Default::default()
        };
        let trainer = cfg.trainer.build(&cfg);
        trainer.fit(&ds, None, &mut ())?;
        let stats = trainer.stats().expect("engine counters");
        let mk = stats.makespan_secs();
        let base = *baseline.get_or_insert(mk);
        println!(
            "{:>14} {:>8} {:>9.3}s {:>9.2}x {:>12}",
            cols,
            dsfacto::nomad::token::n_tokens(ds.d(), cols),
            mk,
            base / mk.max(1e-12),
            stats.messages
        );
    }
    println!("(speedup* relative to single-column tokens; blocking amortizes dispatch)");

    // ---------------------------------------------------------------
    println!("\n== Ablation 2: update-visit semantics (housing twin, P=4) ==");
    let ds = synth::table2_dataset("housing", 7)?;
    let (train, test) = ds.split(0.8, 8);
    let fm4 = FmHyper {
        k: 4,
        ..Default::default()
    };
    println!("{:<34} {:>12} {:>10}", "mode", "objective", "test RMSE");
    for (label, mode, eta, iters) in [
        ("mean-gradient (eta=0.5)", "mean", 0.5f32, 60usize),
        ("stochastic x1 (eta=0.02)", "stochastic:1", 0.02, 60),
        ("stochastic x4 (eta=0.02)", "stochastic:4", 0.02, 60),
    ] {
        let mut cfg = ExperimentConfig {
            dataset: DatasetSpec::Table2("housing".into()),
            trainer: TrainerKind::Nomad,
            fm: fm4,
            workers: 4,
            outer_iters: iters,
            eta: LrSchedule::Constant(eta),
            eval_every: usize::MAX,
            ..Default::default()
        };
        cfg.set("update_mode", mode)?;
        let out = cfg.trainer.build(&cfg).fit(&train, None, &mut ())?;
        let m = dsfacto::metrics::evaluate(&out.model, &test);
        println!(
            "{:<34} {:>12.6} {:>10.5}",
            label,
            out.trace.last().unwrap().objective,
            m.rmse
        );
    }

    // ---------------------------------------------------------------
    println!("\n== Ablation 3: incremental vs bulk synchronization (ijcnn1, P=4) ==");
    let ds = synth::table2_dataset("ijcnn1", 9)?;
    let (train, test) = ds.split(0.8, 10);
    let iters = 15;

    let mk_cfg = |trainer| ExperimentConfig {
        dataset: DatasetSpec::Table2("ijcnn1".into()),
        trainer,
        fm: fm4,
        workers: 4,
        outer_iters: iters,
        eta: LrSchedule::Constant(1.0),
        eval_every: usize::MAX,
        ..Default::default()
    };

    let ncfg = mk_cfg(TrainerKind::Nomad);
    let nomad_trainer = ncfg.trainer.build(&ncfg);
    let nomad = nomad_trainer.fit(&train, None, &mut ())?;
    let nstats = nomad_trainer.stats().expect("engine counters");

    let dcfg = mk_cfg(TrainerKind::Dsgd);
    let dsgd = dcfg.trainer.build(&dcfg).fit(&train, None, &mut ())?;

    let bcfg = mk_cfg(TrainerKind::BulkSync);
    let bulk = bcfg.trainer.build(&bcfg).fit(&train, None, &mut ())?;

    println!(
        "{:<42} {:>12} {:>10} {:>10}",
        "variant", "objective", "test acc", "train-s"
    );
    for (label, out) in [
        ("ds-facto (incremental sync, async ring)", &nomad),
        ("dsgd (bulk sync per sub-epoch, barriers)", &dsgd),
        ("bulk-sync full GD (barrier per iter)", &bulk),
    ] {
        let m = dsfacto::metrics::evaluate(&out.model, &test);
        println!(
            "{:<42} {:>12.6} {:>10.4} {:>9.2}s",
            label,
            out.trace.last().unwrap().objective,
            m.accuracy,
            out.wall_secs
        );
    }
    println!(
        "(ds-facto reaches bulk-sync quality without barriers: {} token hops, holdback peak {})",
        nstats.messages, nstats.holdback_peak
    );

    // ---------------------------------------------------------------
    println!("\n== Ablation 4: row-partition plans (realsim twin, P=8, 2 iters) ==");
    println!(
        "{:>12} {:>10} {:>11} {:>12} {:>12}",
        "plan", "makespan", "imbalance", "max-nnz", "min-nnz"
    );
    let ds = synth::table2_dataset("realsim", 42)?;
    for plan in ["contiguous", "balanced"] {
        let mut cfg = ExperimentConfig {
            dataset: DatasetSpec::Table2("realsim".into()),
            trainer: TrainerKind::Nomad,
            fm: fm16,
            workers: 8,
            outer_iters: 2,
            eta: LrSchedule::Constant(0.5),
            eval_every: usize::MAX,
            ..Default::default()
        };
        cfg.set("row_partition", plan)?;
        let trainer = cfg.trainer.build(&cfg);
        trainer.fit(&ds, None, &mut ())?;
        let stats = trainer.stats().expect("engine counters");
        let ps = &stats.partition;
        println!(
            "{:>12} {:>9.3}s {:>11.3} {:>12} {:>12}",
            plan,
            stats.makespan_secs(),
            ps.imbalance,
            ps.shard_nnz.iter().max().copied().unwrap_or(0),
            ps.shard_nnz.iter().min().copied().unwrap_or(0),
        );
    }
    println!("(same optimization either way; balanced equalizes per-worker nnz on skewed rows)");
    Ok(())
}
