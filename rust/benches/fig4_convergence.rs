//! Figure 4 reproduction: convergence behaviour of DS-FACTO vs libFM on
//! the diabetes, housing and ijcnn1 twins — training objective (eq. 5) as
//! a function of outer iteration and of wall-clock time.
//!
//! Paper's qualitative claim: "DS-FACTO achieves the similar solution as
//! libFM by making updates just on a subset of dimensions per iteration."
//! Run: `cargo bench --bench fig4_convergence`.

use dsfacto::config::{DatasetSpec, ExperimentConfig, TrainerKind};
use dsfacto::data::synth;
use dsfacto::fm::FmHyper;
use dsfacto::metrics::TrainOutput;
use dsfacto::optim::LrSchedule;

struct Setup {
    dataset: &'static str,
    iters: usize,
    nomad_eta: f32,
    libfm_eta: f32,
    libfm_epochs: usize,
}

const SETUPS: &[Setup] = &[
    Setup {
        dataset: "diabetes",
        iters: 60,
        nomad_eta: 0.5,
        libfm_eta: 0.02,
        libfm_epochs: 40,
    },
    Setup {
        dataset: "housing",
        iters: 60,
        nomad_eta: 0.5,
        libfm_eta: 0.02,
        libfm_epochs: 40,
    },
    Setup {
        dataset: "ijcnn1",
        iters: 25,
        nomad_eta: 1.0,
        libfm_eta: 0.01,
        libfm_epochs: 8,
    },
];

fn print_series(label: &str, out: &TrainOutput, every: usize) {
    println!("  {label} (iter, secs, objective):");
    for pt in out.trace.iter().filter(|p| p.iter % every == 0) {
        println!("    {:>4}  {:>9.3}  {:.6}", pt.iter, pt.secs, pt.objective);
    }
}

/// First iteration whose objective is within 5% of the run's best.
fn iters_to_converge(out: &TrainOutput) -> usize {
    let best = out
        .trace
        .iter()
        .map(|p| p.objective)
        .fold(f64::INFINITY, f64::min);
    out.trace
        .iter()
        .find(|p| p.objective <= best * 1.05)
        .map(|p| p.iter)
        .unwrap_or(out.trace.len())
}

fn main() -> anyhow::Result<()> {
    println!("== Figure 4: convergence (objective vs iteration / time) ==");
    let mut rows = Vec::new();
    for s in SETUPS {
        let ds = synth::table2_dataset(s.dataset, 42)?;
        let (train, _test) = ds.split(0.8, 43);
        let fm = FmHyper {
            k: 4,
            ..Default::default()
        };
        println!(
            "\n-- {} (N={}, D={}) --",
            s.dataset,
            train.n(),
            train.d()
        );

        // Both engines run through the uniform Trainer API.
        let mk_cfg = |trainer, iters, eta| ExperimentConfig {
            dataset: DatasetSpec::Table2(s.dataset.into()),
            trainer,
            fm,
            workers: 4,
            outer_iters: iters,
            eta: LrSchedule::Constant(eta),
            eval_every: usize::MAX,
            ..Default::default()
        };
        let ncfg = mk_cfg(TrainerKind::Nomad, s.iters, s.nomad_eta);
        let nomad = ncfg.trainer.build(&ncfg).fit(&train, None, &mut ())?;

        let lcfg = mk_cfg(TrainerKind::Libfm, s.libfm_epochs, s.libfm_eta);
        let libfm = lcfg.trainer.build(&lcfg).fit(&train, None, &mut ())?;

        print_series("ds-facto (P=4)", &nomad, (s.iters / 10).max(1));
        print_series("libfm (1 thread)", &libfm, (s.libfm_epochs / 8).max(1));

        let n_final = nomad.trace.last().unwrap().objective;
        let l_final = libfm.trace.last().unwrap().objective;
        println!(
            "  final objective: ds-facto {:.6} vs libfm {:.6} (gap {:+.2}%)",
            n_final,
            l_final,
            100.0 * (n_final - l_final) / l_final
        );
        println!(
            "  iterations to within 5% of best: ds-facto {} / libfm {}",
            iters_to_converge(&nomad),
            iters_to_converge(&libfm)
        );
        rows.push((s.dataset, n_final, l_final));
    }

    println!("\n== Figure 4 summary (final training objective) ==");
    println!("{:<10} {:>12} {:>12} {:>9}", "dataset", "ds-facto", "libfm", "gap");
    let mut ok = true;
    for (name, n, l) in rows {
        let gap = (n - l) / l;
        println!("{name:<10} {n:>12.6} {l:>12.6} {:>8.2}%", 100.0 * gap);
        ok &= gap < 0.25;
    }
    println!(
        "\npaper shape: DS-FACTO converges to the same objective as libFM — {}",
        if ok { "REPRODUCED" } else { "NOT reproduced" }
    );
    anyhow::ensure!(ok, "convergence parity failed");
    Ok(())
}
