//! Figure 6 reproduction: scalability of DS-FACTO as the number of workers
//! varies over {1, 2, 4, 8, 16, 32}, on both communication axes:
//!
//! * **multi-threaded** — in-process queues (paper's "# threads" panel);
//! * **multi-machine** — serialized tokens through the simulated network
//!   (paper's "# cores/machines" panel; DESIGN.md §2 substitution).
//!
//! This container exposes a single CPU core, so wall-clock cannot show
//! parallel speedup. Speedup is therefore computed from the engine's
//! per-worker busy time as the simulated parallel makespan
//! `T_p = max_p busy_p` (work-span model); wall-clock is also printed.
//! The shape to reproduce: near-linear at small P, flattening as queue
//! overheads dominate; the paper found multi-machine scaling better than
//! multi-threaded (their queues contended) — with lock-free per-worker
//! queues ours contend less, and the network axis instead pays
//! serialization costs.
//!
//! Run: `cargo bench --bench fig6_scalability`.

use dsfacto::cluster::NetModel;
use dsfacto::config::{DatasetSpec, ExperimentConfig, TrainerKind};
use dsfacto::data::synth;
use dsfacto::fm::FmHyper;
use dsfacto::nomad::TransportKind;
use dsfacto::optim::LrSchedule;

fn main() -> anyhow::Result<()> {
    let workers = [1usize, 2, 4, 8, 16, 32];
    let setups = [("ijcnn1", 5usize, 4usize), ("realsim", 2, 16)];

    println!("== Figure 6: scalability (speedup vs #workers) ==");
    println!("(simulated makespan = max_p busy_p; single-core container — see DESIGN.md)");

    for (dataset, iters, k) in setups {
        let ds = synth::table2_dataset(dataset, 42)?;
        let fm = FmHyper {
            k,
            ..Default::default()
        };
        println!(
            "\n-- {dataset}: N={} D={} K={k}, {iters} outer iterations --",
            ds.n(),
            ds.d()
        );

        for (mode, label) in [
            (0, "multi-threaded (in-process)"),
            (1, "multi-machine (simnet 100us/10Gbps)"),
        ] {
            // realsim over simnet serializes D*K floats per token; keep the
            // sweep tractable by skipping the two largest points there.
            let points: Vec<usize> = if mode == 1 && dataset == "realsim" {
                workers.iter().cloned().filter(|&p| p <= 8).collect()
            } else {
                workers.to_vec()
            };
            println!("  [{label}]");
            println!(
                "  {:>8} {:>10} {:>10} {:>9} {:>8} {:>12} {:>12}",
                "workers", "wall-s", "makespan", "speedup", "eff", "msgs", "MB moved"
            );
            let mut base_makespan = None;
            for &p in &points {
                let transport = if mode == 0 {
                    TransportKind::Local
                } else {
                    TransportKind::SimNet(NetModel {
                        latency: std::time::Duration::from_micros(100),
                        bandwidth_bps: 10e9 / 8.0,
                        workers_per_machine: 1,
                    })
                };
                let cfg = ExperimentConfig {
                    dataset: DatasetSpec::Table2(dataset.into()),
                    trainer: TrainerKind::Nomad,
                    fm,
                    workers: p,
                    outer_iters: iters,
                    eta: LrSchedule::Constant(0.5),
                    eval_every: usize::MAX,
                    transport,
                    ..Default::default()
                };
                let trainer = cfg.trainer.build(&cfg);
                let out = trainer.fit(&ds, None, &mut ())?;
                let stats = trainer.stats().expect("engine counters");
                let makespan = stats.makespan_secs();
                let base = *base_makespan.get_or_insert(makespan);
                let speedup = base / makespan.max(1e-12);
                println!(
                    "  {:>8} {:>10.3} {:>10.3} {:>9.2} {:>7.0}% {:>12} {:>12.2}",
                    p,
                    out.wall_secs,
                    makespan,
                    speedup,
                    100.0 * speedup / p as f64,
                    stats.messages,
                    stats.bytes as f64 / 1e6
                );
            }
        }
    }
    println!(
        "\npaper shape: monotone speedup, sub-linear at high P (queue/communication\n\
         overheads); communication-heavy axis scales worse on wide models (realsim)."
    );
    Ok(())
}
