//! Quickstart: train a factorization machine with DS-FACTO through the
//! uniform `Trainer` API, score it through both `Predictor` backends
//! (native Rust and the AOT XLA artifact), and save the model.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use dsfacto::coordinator::Evaluator;
use dsfacto::fm::io;
use dsfacto::metrics::evaluate;
use dsfacto::prelude::*;
use dsfacto::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    // 1. Configure: the diabetes twin (513 examples, 8 features,
    //    classification; Table 2) trained by the DS-FACTO engine. Swapping
    //    `trainer` for Libfm / Dsgd / BulkSync changes nothing below —
    //    every engine implements the same `Trainer` trait.
    let cfg = ExperimentConfig {
        dataset: DatasetSpec::Table2("diabetes".into()),
        trainer: TrainerKind::Nomad,
        workers: 4,
        outer_iters: 60,
        eta: dsfacto::optim::LrSchedule::Constant(0.5),
        ..Default::default()
    };
    let ds = cfg.dataset.load(42)?;
    let (train, test) = ds.split(0.8, 7);
    println!(
        "dataset {}: {} train / {} test examples, {} features",
        ds.name,
        train.n(),
        test.n(),
        train.d()
    );

    // 2. Train: hybrid-parallel, no parameter server — the parameter
    //    columns circulate as tokens. The observer records every trace
    //    point as the session runs.
    let trainer = cfg.trainer.build(&cfg);
    let mut recorder = TraceRecorder::default();
    let out = trainer.fit(&train, Some(&test), &mut recorder)?;
    println!(
        "trained {} in {:.2}s: objective {:.4} -> {:.4} over {} outer iterations",
        trainer.name(),
        out.wall_secs,
        out.trace.first().unwrap().objective,
        out.trace.last().unwrap().objective,
        cfg.outer_iters
    );
    let stats = trainer.stats().expect("the DS-FACTO engine reports counters");
    println!(
        "engine moved {} tokens ({} update visits, {} coordinate updates); observer saw {} points",
        stats.messages,
        stats.update_visits,
        stats.coordinate_updates,
        recorder.trace.len()
    );

    // 3. Evaluate: Rust scorer...
    let m = evaluate(&out.model, &test);
    println!("test accuracy {:.4}, AUC {:.4} (rust scorer)", m.accuracy, m.auc);

    //    ...and the AOT XLA artifact, reached through the same `Predictor`
    //    trait as the native model (the request-path scorer), when built.
    if Runtime::available("artifacts") {
        let xla = Evaluator::for_dataset("artifacts", &test)?
            .into_predictor(out.model.clone())?;
        let native_scores = Predictor::predict_dataset(&out.model, &test)?;
        let xla_scores = xla.predict_dataset(&test)?;
        let max_delta = native_scores
            .iter()
            .zip(&xla_scores)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        println!(
            "XLA artifact (Pallas kernel inside) agrees with the native scorer: max |delta| = {max_delta:.2e}"
        );
    } else {
        println!("(run `make artifacts` to also score through the XLA predictor)");
    }

    // 4. Persist.
    let path = std::env::temp_dir().join("dsfacto_quickstart.dsfm");
    io::save(&out.model, &path)?;
    println!("model saved to {}", path.display());
    Ok(())
}
