//! Quickstart: train a factorization machine with DS-FACTO on the
//! diabetes twin (Table 2), evaluate it through both the Rust scorer and
//! the AOT XLA artifact, and save the model.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use dsfacto::coordinator::Evaluator;
use dsfacto::data::synth;
use dsfacto::fm::{io, FmHyper};
use dsfacto::metrics::evaluate;
use dsfacto::nomad::{train_with_stats, NomadConfig};
use dsfacto::optim::LrSchedule;
use dsfacto::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    // 1. Data: a synthetic twin of the paper's `diabetes` dataset
    //    (513 examples, 8 features, classification; DESIGN.md §2).
    let ds = synth::table2_dataset("diabetes", 42)?;
    let (train, test) = ds.split(0.8, 7);
    println!(
        "dataset {}: {} train / {} test examples, {} features",
        ds.name,
        train.n(),
        test.n(),
        train.d()
    );

    // 2. Train with DS-FACTO: 4 workers, hybrid-parallel, no parameter
    //    server — the parameter columns circulate as tokens.
    let fm = FmHyper {
        k: 4,
        lambda_w: 1e-4,
        lambda_v: 1e-4,
        ..Default::default()
    };
    let cfg = NomadConfig {
        workers: 4,
        outer_iters: 60,
        eta: LrSchedule::Constant(0.5),
        ..Default::default()
    };
    let (out, stats) = train_with_stats(&train, Some(&test), &fm, &cfg)?;
    println!(
        "trained in {:.2}s: objective {:.4} -> {:.4} over {} outer iterations",
        out.wall_secs,
        out.trace.first().unwrap().objective,
        out.trace.last().unwrap().objective,
        cfg.outer_iters
    );
    println!(
        "engine moved {} tokens ({} update visits, {} coordinate updates)",
        stats.messages, stats.update_visits, stats.coordinate_updates
    );

    // 3. Evaluate: Rust scorer...
    let m = evaluate(&out.model, &test);
    println!("test accuracy {:.4}, AUC {:.4} (rust scorer)", m.accuracy, m.auc);

    //    ...and the AOT XLA artifact (the request-path scorer), when built.
    if Runtime::available("artifacts") {
        let eval = Evaluator::for_dataset("artifacts", &test)?;
        let mx = eval.evaluate(&out.model, &test)?;
        println!(
            "test accuracy {:.4}, AUC {:.4} (XLA artifact — Pallas kernel inside)",
            mx.accuracy, mx.auc
        );
    } else {
        println!("(run `make artifacts` to also evaluate through the XLA path)");
    }

    // 4. Persist.
    let path = std::env::temp_dir().join("dsfacto_quickstart.dsfm");
    io::save(&out.model, &path)?;
    println!("model saved to {}", path.display());
    Ok(())
}
