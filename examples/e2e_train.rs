//! End-to-end validation driver (DESIGN.md §5): train the paper's largest
//! workload — the realsim twin (50,616 examples, 20,958 features, K=16,
//! ~0.25% dense) — through the uniform `Trainer` API, log the convergence
//! curve, and validate the XLA request path on the trained model. The run
//! is recorded in EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example e2e_train [-- --iters 20 --workers 8 --dataset realsim]
//! ```

use dsfacto::coordinator::{write_trace_csv, Evaluator};
use dsfacto::data::synth;
use dsfacto::metrics::evaluate;
use dsfacto::prelude::*;
use dsfacto::runtime::Runtime;
use dsfacto::util::cli::Args;
use dsfacto::util::{human_bytes, human_secs};

fn main() -> anyhow::Result<()> {
    let mut args = Args::from_env()?;
    let dataset: String = args.get_or("dataset", "realsim".to_string())?;
    let workers: usize = args.get_or("workers", 8)?;
    let iters: usize = args.get_or("iters", 20)?;
    let eta: String = args.get_or("eta", "inv:2.0,0.15".to_string())?;
    let trace_out: String =
        args.get_or("trace", "/tmp/dsfacto_e2e_trace.csv".to_string())?;
    args.finish()?;

    println!("== DS-FACTO end-to-end validation: {dataset} twin ==");
    let ds = synth::table2_dataset(&dataset, 4242)?;
    let (train, test) = ds.split(0.8, 11);
    let mut cfg = ExperimentConfig {
        dataset: DatasetSpec::Table2(dataset.clone()),
        trainer: TrainerKind::Nomad,
        workers,
        outer_iters: iters,
        eval_every: 2,
        ..Default::default()
    };
    cfg.fm = FmHyper {
        k: synth::SynthSpec::table2(&dataset)?.k,
        lambda_w: 1e-5,
        lambda_v: 1e-5,
        ..Default::default()
    };
    cfg.set("eta", &eta)?;
    let n_params = 1 + train.d() * (cfg.fm.k + 1);
    println!(
        "data: {} train / {} test, D={}, nnz(train)={} ({:.3}% dense)",
        train.n(),
        test.n(),
        train.d(),
        train.nnz(),
        100.0 * train.density()
    );
    println!(
        "model: K={}, {} parameters ({})",
        cfg.fm.k,
        n_params,
        human_bytes(n_params * 4)
    );
    println!(
        "engine: {} workers, {} outer iterations, {} tokens in flight\n",
        workers,
        iters,
        train.d() + 1
    );

    let trainer = cfg.trainer.build(&cfg);
    let out = trainer.fit(&train, Some(&test), &mut ())?;
    let stats = trainer.stats().expect("engine counters");

    println!("{:>5} {:>10} {:>12} {:>12} {:>10}", "iter", "time", "objective", "train_loss", "test_acc");
    for pt in &out.trace {
        let acc = pt
            .test
            .map(|m| format!("{:.4}", m.accuracy))
            .unwrap_or_else(|| "-".into());
        println!(
            "{:>5} {:>10} {:>12.6} {:>12.6} {:>10}",
            pt.iter,
            human_secs(pt.secs),
            pt.objective,
            pt.train_loss,
            acc
        );
    }
    let first = out.trace.first().unwrap().objective;
    let last = out.trace.last().unwrap().objective;
    println!(
        "\ntrained in {}: objective {:.4} -> {:.4} ({:.1}% reduction)",
        human_secs(out.wall_secs),
        first,
        last,
        100.0 * (1.0 - last / first)
    );
    println!(
        "engine counters: {} token hops, {} coordinate updates ({:.1}M/s/worker), holdback peak {}",
        stats.messages,
        stats.coordinate_updates,
        stats.coordinate_updates as f64 / out.wall_secs / workers as f64 / 1e6,
        stats.holdback_peak
    );

    let m = evaluate(&out.model, &test);
    println!("final test accuracy {:.4}, AUC {:.4} (rust scorer)", m.accuracy, m.auc);

    // Request path: score the test set through the AOT XLA artifact
    // (Pallas kernel inside) and check agreement.
    if Runtime::available("artifacts") {
        let eval = Evaluator::for_dataset("artifacts", &test)?;
        let sw = std::time::Instant::now();
        let mx = eval.evaluate(&out.model, &test)?;
        println!(
            "final test accuracy {:.4}, AUC {:.4} (XLA request path, {:.2}s for {} examples)",
            mx.accuracy,
            mx.auc,
            sw.elapsed().as_secs_f64(),
            test.n()
        );
        anyhow::ensure!(
            (mx.accuracy - m.accuracy).abs() < 1e-9,
            "XLA and Rust paths disagree"
        );
    } else {
        println!("(artifacts not built; skipping XLA request-path validation)");
    }

    write_trace_csv(&trace_out, &out)?;
    println!("trace written to {trace_out}");
    anyhow::ensure!(last < first, "objective did not descend");
    Ok(())
}
