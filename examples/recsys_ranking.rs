//! Recommender-system example: FMs subsume matrix factorization when the
//! features are one-hot (user, item) pairs (Rendle 2010, §V). We simulate a
//! ratings matrix with latent user/item structure, encode each rating as a
//! sparse two-hot FM example, train with DS-FACTO through the `Trainer`
//! API, and rank held-out items per user through the `Predictor` API.
//!
//! ```bash
//! cargo run --release --example recsys_ranking [-- --users 400 --items 300]
//! ```

use dsfacto::data::Csr;
use dsfacto::metrics::evaluate;
use dsfacto::prelude::*;
use dsfacto::util::cli::Args;

/// Builds a two-hot (user, item) ratings dataset from planted latent
/// factors: rating = <p_u, q_i> + bias terms + noise, standardized.
fn build_ratings(users: usize, items: usize, per_user: usize, seed: u64) -> (Dataset, Vec<(usize, usize)>) {
    let mut rng = Pcg64::seeded(seed);
    let latent = 4usize;
    let p: Vec<f32> = (0..users * latent).map(|_| rng.normal32(0.0, 0.7)).collect();
    let q: Vec<f32> = (0..items * latent).map(|_| rng.normal32(0.0, 0.7)).collect();
    let bu: Vec<f32> = (0..users).map(|_| rng.normal32(0.0, 0.3)).collect();
    let bi: Vec<f32> = (0..items).map(|_| rng.normal32(0.0, 0.3)).collect();

    let mut triplets = Vec::new();
    let mut labels = Vec::new();
    let mut pairs = Vec::new();
    let mut row = 0usize;
    for u in 0..users {
        let chosen = rng.sample_indices(items, per_user.min(items));
        for i in chosen {
            // two-hot encoding: feature u and feature users+i set to 1.
            triplets.push((row, u, 1.0));
            triplets.push((row, users + i, 1.0));
            let dot: f32 = (0..latent).map(|k| p[u * latent + k] * q[i * latent + k]).sum();
            labels.push(dot + bu[u] + bi[i] + rng.normal32(0.0, 0.3));
            pairs.push((u, i));
            row += 1;
        }
    }
    // Standardize ratings.
    let mean = labels.iter().sum::<f32>() / labels.len() as f32;
    let std = (labels.iter().map(|y| (y - mean) * (y - mean)).sum::<f32>() / labels.len() as f32)
        .sqrt()
        .max(1e-6);
    for y in labels.iter_mut() {
        *y = (*y - mean) / std;
    }
    let rows = Csr::from_triplets(row, users + items, &triplets);
    (
        Dataset {
            name: "recsys".into(),
            task: Task::Regression,
            rows,
            labels,
        },
        pairs,
    )
}

fn main() -> anyhow::Result<()> {
    let mut args = Args::from_env()?;
    let users: usize = args.get_or("users", 400)?;
    let items: usize = args.get_or("items", 300)?;
    let per_user: usize = args.get_or("per-user", 30)?;
    let workers: usize = args.get_or("workers", 4)?;
    let iters: usize = args.get_or("iters", 800)?;
    let eta: String = args.get_or("eta", "constant:0.01".to_string())?;
    let samples: usize = args.get_or("samples", 4)?;
    args.finish()?;

    let (ds, pairs) = build_ratings(users, items, per_user, 2024);
    let (train_ds, test_ds) = ds.split(0.85, 5);
    println!(
        "ratings: {} users x {} items, {} ratings ({} train / {} test), D = {}",
        users,
        items,
        ds.n(),
        train_ds.n(),
        test_ds.n(),
        ds.d()
    );

    // K=8 FM over the two-hot encoding == biased matrix factorization with
    // rank-8 embeddings, trained hybrid-parallel.
    // Matrix-factorization-style problems need stochastic noise to grow
    // the factors out of the V~0 saddle, so this run uses the
    // paper-literal stochastic update mode (Algorithm 1 line 14): each
    // token visit applies per-example eq. 12/13 updates for a handful of
    // sampled local ratings, at per-example-SGD step sizes. Both engine
    // knobs are plain config keys now.
    let mut cfg = ExperimentConfig {
        trainer: TrainerKind::Nomad,
        fm: FmHyper {
            k: 8,
            lambda_w: 1e-4,
            lambda_v: 1e-4,
            init_std: 0.1,
        },
        workers,
        outer_iters: iters,
        eval_every: usize::MAX,
        ..Default::default()
    };
    cfg.set("eta", &eta)?;
    cfg.set("update_mode", &format!("stochastic:{samples}"))?;
    let out = cfg.trainer.build(&cfg).fit(&train_ds, None, &mut ())?;
    let m = evaluate(&out.model, &test_ds);
    println!(
        "trained {} outer iters in {:.2}s: test RMSE {:.4} (label std = 1.0)",
        iters, out.wall_secs, m.rmse
    );
    anyhow::ensure!(m.rmse < 0.7, "FM failed to learn the latent structure");

    // Rank: for user 0, score every item through the Predictor trait and
    // show the top 5.
    let u = pairs[0].0;
    let mut scored: Vec<(usize, f32)> = (0..items)
        .map(|i| {
            let idx = [u as u32, (users + i) as u32];
            let val = [1.0f32, 1.0];
            (i, out.model.predict_one(&idx, &val).expect("in-range features"))
        })
        .collect();
    scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!("\ntop-5 recommendations for user {u}:");
    for (rank, (item, score)) in scored.iter().take(5).enumerate() {
        println!("  #{:<2} item {:<4} predicted rating {:+.3}", rank + 1, item, score);
    }
    Ok(())
}
