//! Scaling study: the Fig. 6 experiment as a runnable example. Sweeps the
//! worker count over {1, 2, 4, 8, ...} in both communication modes
//! (in-process threads vs simulated multi-machine network) and prints
//! speedup tables. The transport is an `ExperimentConfig` key, so every
//! point runs through the same `TrainerKind::build` dispatch as the CLI.
//!
//! ```bash
//! cargo run --release --example scaling_study [-- --dataset ijcnn1 --workers 1,2,4,8]
//! ```

use dsfacto::data::synth;
use dsfacto::optim::LrSchedule;
use dsfacto::prelude::*;
use dsfacto::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let mut args = Args::from_env()?;
    let dataset: String = args.get_or("dataset", "ijcnn1".to_string())?;
    let workers = args.get_list("workers", &[1usize, 2, 4, 8])?;
    let iters: usize = args.get_or("iters", 5)?;
    args.finish()?;

    let ds = synth::table2_dataset(&dataset, 42)?;
    let fm = FmHyper {
        k: 4,
        ..Default::default()
    };
    println!(
        "scaling study on {dataset}: N={} D={} K={} — {iters} outer iterations per point\n",
        ds.n(),
        ds.d(),
        fm.k
    );

    for (transport, label) in [
        ("local", "multi-threaded (in-process queues)"),
        ("simnet:100us,1.25e9,1", "simulated multi-machine (100us / 10Gbps)"),
    ] {
        println!("== {label} ==");
        println!(
            "{:>8} {:>10} {:>10} {:>9} {:>9} {:>12}",
            "workers", "wall-s", "makespan", "speedup", "eff", "msgs"
        );
        let mut base = None;
        for &p in &workers {
            let mut cfg = ExperimentConfig {
                dataset: DatasetSpec::Table2(dataset.clone()),
                trainer: TrainerKind::Nomad,
                fm,
                workers: p,
                outer_iters: iters,
                eta: LrSchedule::Constant(0.5),
                eval_every: usize::MAX,
                ..Default::default()
            };
            cfg.set("transport", transport)?;
            let trainer = cfg.trainer.build(&cfg);
            let out = trainer.fit(&ds, None, &mut ())?;
            let stats = trainer.stats().expect("engine counters");
            // Single-core container: wall-clock cannot show parallelism, so
            // speedup uses the simulated parallel makespan max_p(busy_p)
            // (same convention as the fig6_scalability bench).
            let makespan = stats.makespan_secs();
            let base_secs = *base.get_or_insert(makespan);
            let speedup = base_secs / makespan.max(1e-12);
            println!(
                "{:>8} {:>10.3} {:>10.3} {:>9.2} {:>8.0}% {:>12}",
                p,
                out.wall_secs,
                makespan,
                speedup,
                100.0 * speedup / p as f64,
                stats.messages
            );
        }
        println!();
    }
    println!("(dotted line in paper Fig. 6 = linear speedup; efficiency = speedup/P)");
    Ok(())
}
