//! Scaling study: the Fig. 6 experiment as a runnable example. Sweeps the
//! worker count over {1, 2, 4, 8, ...} in both communication modes
//! (in-process threads vs simulated multi-machine network) and prints
//! speedup tables.
//!
//! ```bash
//! cargo run --release --example scaling_study [-- --dataset ijcnn1 --workers 1,2,4,8]
//! ```

use dsfacto::cluster::NetModel;
use dsfacto::data::synth;
use dsfacto::fm::FmHyper;
use dsfacto::nomad::{train_with_stats, NomadConfig, TransportKind};
use dsfacto::optim::LrSchedule;
use dsfacto::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let mut args = Args::from_env()?;
    let dataset: String = args.get_or("dataset", "ijcnn1".to_string())?;
    let workers = args.get_list("workers", &[1usize, 2, 4, 8])?;
    let iters: usize = args.get_or("iters", 5)?;
    args.finish()?;

    let ds = synth::table2_dataset(&dataset, 42)?;
    let fm = FmHyper {
        k: 4,
        ..Default::default()
    };
    println!(
        "scaling study on {dataset}: N={} D={} K={} — {iters} outer iterations per point\n",
        ds.n(),
        ds.d(),
        fm.k
    );

    for (mode, label) in [(0, "multi-threaded (in-process queues)"), (1, "simulated multi-machine (100us / 10Gbps)")] {
        println!("== {label} ==");
        println!(
            "{:>8} {:>10} {:>10} {:>9} {:>9} {:>12}",
            "workers", "wall-s", "makespan", "speedup", "eff", "msgs"
        );
        let mut base = None;
        for &p in &workers {
            let transport = if mode == 0 {
                TransportKind::Local
            } else {
                TransportKind::SimNet(NetModel {
                    latency: std::time::Duration::from_micros(100),
                    bandwidth_bps: 10e9 / 8.0,
                    workers_per_machine: 1,
                })
            };
            let cfg = NomadConfig {
                workers: p,
                outer_iters: iters,
                eta: LrSchedule::Constant(0.5),
                eval_every: usize::MAX,
                transport,
                ..Default::default()
            };
            let (out, stats) = train_with_stats(&ds, None, &fm, &cfg)?;
            // Single-core container: wall-clock cannot show parallelism, so
            // speedup uses the simulated parallel makespan max_p(busy_p)
            // (same convention as the fig6_scalability bench).
            let makespan = stats.makespan_secs();
            let base_secs = *base.get_or_insert(makespan);
            let speedup = base_secs / makespan.max(1e-12);
            println!(
                "{:>8} {:>10.3} {:>10.3} {:>9.2} {:>8.0}% {:>12}",
                p,
                out.wall_secs,
                makespan,
                speedup,
                100.0 * speedup / p as f64,
                stats.messages
            );
        }
        println!();
    }
    println!("(dotted line in paper Fig. 6 = linear speedup; efficiency = speedup/P)");
    Ok(())
}
