//! Scaling study: the Fig. 6 experiment as a runnable example, on the
//! ingest-first out-of-core flow. The dataset is written once as LIBSVM
//! text, `stream_ingest`ed into a P-shard binary cache per worker count,
//! and every point trains through `run_experiment` on `cache:<dir>` with
//! `train_frac = 1` — the coordinator streams shards through the
//! double-buffered prefetcher and never materializes the full matrix
//! (each row reports its measured peak residency). The sweep covers both
//! communication modes (in-process threads vs simulated multi-machine
//! network); the transport is an `ExperimentConfig` key, so every point
//! runs through the same `TrainerKind::build` dispatch as the CLI.
//!
//! ```bash
//! cargo run --release --example scaling_study [-- --dataset ijcnn1 --workers 1,2,4,8]
//! ```

use dsfacto::coordinator::run_experiment;
use dsfacto::data::libsvm::{self, IngestOptions};
use dsfacto::data::synth;
use dsfacto::optim::LrSchedule;
use dsfacto::partition::RowStrategy;
use dsfacto::prelude::*;
use dsfacto::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let mut args = Args::from_env()?;
    let dataset: String = args.get_or("dataset", "ijcnn1".to_string())?;
    let workers = args.get_list("workers", &[1usize, 2, 4, 8])?;
    let iters: usize = args.get_or("iters", 5)?;
    args.finish()?;

    let ds = synth::table2_dataset(&dataset, 42)?;
    let fm = FmHyper {
        k: 4,
        ..Default::default()
    };
    println!(
        "scaling study on {dataset}: N={} D={} K={} — {iters} outer iterations per point\n",
        ds.n(),
        ds.d(),
        fm.k
    );

    // Ingest-first: one LIBSVM file, one P-shard cache per sweep point
    // (the cache bakes in its shard count, so each worker width gets its
    // own ingest — exactly the `dsfacto ingest` + `--dataset cache:DIR`
    // flow).
    let base_dir = std::env::temp_dir().join("dsfacto_scaling_study");
    std::fs::remove_dir_all(&base_dir).ok();
    std::fs::create_dir_all(&base_dir)?;
    let svm_path = base_dir.join(format!("{dataset}.svm"));
    libsvm::save(&ds, &svm_path)?;
    let mut caches = std::collections::BTreeMap::new();
    for &p in &workers {
        let cache_dir = base_dir.join(format!("cache_p{p}"));
        let opts = IngestOptions {
            task: ds.task,
            n_features: Some(ds.d()),
            strategy: RowStrategy::Contiguous,
            shards: p,
            chunk_rows: 4096,
        };
        let report = libsvm::stream_ingest(&svm_path, &dataset, &opts, &cache_dir)?;
        println!(
            "ingested {} rows into {p} shard(s) (peak resident {} B, full CSR never built)",
            report.n, report.peak_resident_bytes
        );
        caches.insert(p, cache_dir);
    }
    println!();

    for (transport, label) in [
        ("local", "multi-threaded (in-process queues)"),
        ("simnet:100us,1.25e9,1", "simulated multi-machine (100us / 10Gbps)"),
    ] {
        println!("== {label} ==");
        println!(
            "{:>8} {:>10} {:>10} {:>9} {:>9} {:>12} {:>14}",
            "workers", "wall-s", "makespan", "speedup", "eff", "msgs", "peak-resident"
        );
        let mut base = None;
        for &p in &workers {
            let mut cfg = ExperimentConfig {
                dataset: DatasetSpec::Cache {
                    dir: caches[&p].to_str().unwrap().to_string(),
                },
                trainer: TrainerKind::Nomad,
                fm,
                workers: p,
                outer_iters: iters,
                eta: LrSchedule::Constant(0.5),
                eval_every: usize::MAX,
                train_frac: 1.0,
                ..Default::default()
            };
            cfg.set("transport", transport)?;
            let summary = run_experiment(&cfg)?;
            let stats = summary.stats.expect("engine counters");
            // Single-core container: wall-clock cannot show parallelism, so
            // speedup uses the simulated parallel makespan max_p(busy_p)
            // (same convention as the fig6_scalability bench).
            let makespan = stats.makespan_secs();
            let base_secs = *base.get_or_insert(makespan);
            let speedup = base_secs / makespan.max(1e-12);
            let resident = summary
                .residency
                .map(|r| format!("{} B", r.peak_resident_bytes))
                .unwrap_or_else(|| "-".to_string());
            println!(
                "{:>8} {:>10.3} {:>10.3} {:>9.2} {:>8.0}% {:>12} {:>14}",
                p,
                summary.output.wall_secs,
                makespan,
                speedup,
                100.0 * speedup / p as f64,
                stats.messages,
                resident
            );
        }
        println!();
    }
    println!("(dotted line in paper Fig. 6 = linear speedup; efficiency = speedup/P)");
    std::fs::remove_dir_all(&base_dir).ok();
    Ok(())
}
