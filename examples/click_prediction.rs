//! Click-through-rate prediction: the workload that motivates the paper's
//! introduction (criteo-style sparse logs). We build a high-dimensional,
//! very sparse synthetic click log (hashed categorical features, Zipf
//! popularity, like real ad logs) and compare DS-FACTO against the libFM
//! baseline on logloss/AUC — the Fig. 4/5 comparison on a CTR workload.
//! Both engines run through the same `Trainer` interface.
//!
//! ```bash
//! cargo run --release --example click_prediction [-- --rows 20000 --dims 5000 --workers 4]
//! ```

use dsfacto::data::synth;
use dsfacto::metrics::evaluate;
use dsfacto::optim::LrSchedule;
use dsfacto::prelude::*;
use dsfacto::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let mut args = Args::from_env()?;
    let rows: usize = args.get_or("rows", 20_000)?;
    let dims: usize = args.get_or("dims", 5_000)?;
    let workers: usize = args.get_or("workers", 4)?;
    let iters: usize = args.get_or("iters", 25)?;
    args.finish()?;

    // A CTR log: ~30 active hashed features per impression out of `dims`,
    // Zipf-distributed popularity (campaign/site ids follow power laws).
    let spec = synth::SynthSpec {
        name: "ctr".into(),
        task: Task::Classification,
        n: rows,
        d: dims,
        k: 8,
        density: 30.0 / dims as f64,
        factor_scale: 0.2,
        noise: 0.5,
        skew: 1.05,
    };
    let out = synth::generate(&spec, 1234);
    let ds = out.dataset;
    let (train, test) = ds.split(0.9, 99);
    let ctr = train.labels.iter().filter(|&&y| y > 0.0).count() as f64 / train.n() as f64;
    println!(
        "click log: {} impressions, {} hashed features, {:.2} nnz/row, base CTR {:.3}",
        ds.rows.n_rows(),
        dims,
        train.nnz() as f64 / train.n() as f64,
        ctr
    );

    let fm = FmHyper {
        k: 8,
        lambda_w: 1e-5,
        lambda_v: 1e-5,
        ..Default::default()
    };

    // DS-FACTO: hybrid-parallel across `workers` threads.
    let nomad_cfg = ExperimentConfig {
        trainer: TrainerKind::Nomad,
        fm,
        workers,
        outer_iters: iters,
        eta: LrSchedule::Constant(1.0),
        eval_every: usize::MAX,
        ..Default::default()
    };
    let nomad_trainer = nomad_cfg.trainer.build(&nomad_cfg);
    let nomad = nomad_trainer.fit(&train, None, &mut ())?;
    let nm = evaluate(&nomad.model, &test);
    println!(
        "ds-facto  ({workers} workers, {iters} iters): {:>8.2}s  logloss {:.4}  acc {:.4}  AUC {:.4}",
        nomad.wall_secs, nm.loss, nm.accuracy, nm.auc
    );
    let stats = nomad_trainer.stats().expect("engine counters");
    println!(
        "          tokens moved: {}  coordinate updates: {}",
        stats.messages, stats.coordinate_updates
    );

    // libFM baseline: single-machine SGD over all dims per example.
    let libfm_epochs = (iters / 5).max(3);
    let libfm_cfg = ExperimentConfig {
        trainer: TrainerKind::Libfm,
        fm,
        outer_iters: libfm_epochs,
        eta: LrSchedule::Constant(0.05),
        eval_every: usize::MAX,
        ..Default::default()
    };
    let libfm = libfm_cfg.trainer.build(&libfm_cfg).fit(&train, None, &mut ())?;
    let lm = evaluate(&libfm.model, &test);
    println!(
        "libfm     (1 thread, {} epochs):  {:>8.2}s  logloss {:.4}  acc {:.4}  AUC {:.4}",
        libfm_epochs, libfm.wall_secs, lm.loss, lm.accuracy, lm.auc
    );

    println!(
        "\npaper claim (Figs. 4-5): the hybrid-parallel optimizer matches the\n\
         single-machine baseline's quality — delta(AUC) = {:+.4}",
        nm.auc - lm.auc
    );
    Ok(())
}
